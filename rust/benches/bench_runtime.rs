//! Bench: runtime hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! Measures the per-call latency of every engine dispatch kind, the fused
//! multi-block grad/normal-matvec path against the per-block reference,
//! per-round host<->device traffic under the session upload pool, block
//! packing + upload cost, a collective round, one full MP-DSVRG outer
//! step, the chained all-reduce across cluster sizes beyond the
//! `redm{2,4,8}` artifact set (asserting the host fallback is honestly
//! metered), the shard plane's engine-per-worker speedup (shards=N
//! wall-clock must beat shards=1 on the multi-machine workload), and the
//! DataPlane draw verb's draw+pack throughput (sequential vs
//! shard-resident draws, with the held draw's per-machine peak-vector
//! meter recorded), the prefetch lane's dispatch-stall comparison
//! (prefetch on vs off: takes, hit rates, per-shard stall time), and the
//! batched-fan pipeline comparison (pipeline on vs off: overlap meters,
//! per-shard overlap time, serialized-vs-pipelined wall-clock), the
//! upload-lane comparison (upload on vs off: staged transfers and
//! overlappable/waited time, with upload counts and bytes asserted
//! bit-identical either way), and the
//! fault-injection degradation benchmark (mp-dsvrg vs minibatch-SGD
//! simulated time under increasing straggler severity, plus a seeded
//! dropout/re-entry run — all counters deterministic from the seed, so
//! they gate structurally in BENCH_baseline.json), and the serve
//! concurrent-clients scenario (cold vs warm executable-cache compile
//! cost, runs/sec and p50/p99 queue-to-done latency under parallel
//! clients of one warm `mbprox serve` pool). Writes
//! `BENCH_runtime.json` (stats + engine traffic counters) so the perf
//! trajectory is trackable across PRs; CI diffs the counters against the
//! committed `BENCH_baseline.json` via the `bench_gate` binary.

use mbprox::accounting::{ClusterMeter, DeviceTraffic};
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::coordinator::Runner;
use mbprox::data::blocks::{pack_all, pack_block};
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::objective::{distributed_mean_grad, distributed_mean_grad_dev, MachineBatch};
use mbprox::runtime::exec::BlockLits;
use mbprox::util::benchkit::{bench, bench_batched, section, JsonReport};

/// POST one run to the serve endpoint and block to its `done` event:
/// returns the queue-to-done latency (ns) and the job's cache delta.
/// Top-level (not a closure) so concurrent client threads can call it.
fn serve_post_timed(
    addr: std::net::SocketAddr,
    body: &str,
) -> (f64, mbprox::accounting::CacheMeter) {
    use mbprox::accounting::CacheMeter;
    use mbprox::util::json::Json;
    let t0 = std::time::Instant::now();
    let mut s = mbprox::serve::http_request(addr, "POST", "/run", body).expect("POST /run");
    assert_eq!(s.status, 200, "accepted run streams 200");
    let mut cache = None;
    while let Some(line) = s.next_line() {
        if line.contains("\"event\":\"error\"") {
            panic!("serve job failed: {line}");
        }
        if line.contains("\"event\":\"done\"") {
            let ev = Json::parse(&line).expect("done event json");
            let c = ev.get("run").and_then(|r| r.get("cache")).expect("cache meter");
            let f = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            cache = Some(CacheMeter {
                hits: f("hits"),
                misses: f("misses"),
                compile_ns: f("compile_ns"),
                evictions: f("evictions"),
            });
        }
    }
    (t0.elapsed().as_nanos() as f64, cache.expect("stream ended without a done event"))
}

/// Nearest-rank percentile over an ascending-sorted latency sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    runner.engine.warmup_all().expect("warmup");
    let engine = &mut runner.engine;
    let mut report = JsonReport::new();
    // the process-level plane policy, recorded so cross-PR comparisons
    // know which plane the tagged scenarios resolved to
    report.note("plane.policy", runner.plane.as_str());

    section("engine dispatch latency (interpret-mode Pallas on CPU PJRT)");
    for (loss, d) in [(Loss::Squared, 64usize), (Loss::Squared, 128), (Loss::Logistic, 64)] {
        let spec = match loss {
            Loss::Squared => SynthSpec::least_squares(d),
            Loss::Logistic => SynthSpec::logistic(d),
        };
        let mut stream = SynthStream::new(spec, 1);
        let samples = stream.draw_many(256);
        let block = pack_block(&samples, d);
        let lits = BlockLits::from_block(engine, &block).unwrap();
        let w = vec![0.01f32; d];

        let s = bench(&format!("grad_{}_d{d} (256 rows)", loss.tag()), 3, 50, || {
            engine.grad_block(loss, &lits, &w).unwrap();
        });
        println!("{}", s.report());
        report.push(&s);

        if loss == Loss::Squared {
            let s = bench(&format!("nm_sq_d{d} (256 rows)"), 3, 50, || {
                engine.nm_block(&lits, &w).unwrap();
            });
            println!("{}", s.report());
            report.push(&s);
        }

        let z = vec![0.0f32; d];
        let s = bench(&format!("svrg_{}_d{d} (256-row sweep)", loss.tag()), 3, 20, || {
            engine
                .svrg_block(loss, &lits, &w, &z, &z, &z, 0.5, 0.05)
                .unwrap();
        });
        println!("{}", s.report());
        report.push(&s);
    }

    section("fused multi-block dispatch vs per-block (d=64, 8 blocks)");
    {
        let widths = engine.fuse_widths();
        println!("manifest fuse widths: {widths:?}");
        let n_blocks = 8usize;
        for loss in [Loss::Squared, Loss::Logistic] {
            let spec = match loss {
                Loss::Squared => SynthSpec::least_squares(64),
                Loss::Logistic => SynthSpec::logistic(64),
            };
            let mut stream = SynthStream::new(spec, 5);
            let samples = stream.draw_many(n_blocks * 256);
            let blocks = pack_all(&samples, 64);
            let per: Vec<BlockLits> =
                blocks.iter().map(|b| BlockLits::from_block(engine, b).unwrap()).collect();
            let batch = MachineBatch::pack(engine, 64, &samples).unwrap();
            let w = vec![0.01f32; 64];
            let tag = loss.tag();

            // seed path: one dispatch + one download per 256-row block
            let s_per =
                bench_batched(&format!("grad_{tag}_d64 per-block x{n_blocks}"), 2, 30, || {
                    for blk in &per {
                        engine.grad_block(loss, blk, &w).unwrap();
                    }
                    n_blocks
                });
            println!("{}", s_per.report());
            report.push(&s_per);

            // fused path: gradm{K} artifacts reduce across blocks on device
            let s_fused = bench_batched(&format!("grad_{tag}_d64 fused x{n_blocks}"), 2, 30, || {
                for blk in &batch.groups {
                    engine.grad_block(loss, blk, &w).unwrap();
                }
                n_blocks
            });
            println!("{}", s_fused.report());
            report.push(&s_fused);

            let speedup = s_per.mean_ns / s_fused.mean_ns.max(1.0);
            println!("  -> fused speedup (per 256-row block): {speedup:.2}x");
            report.counter(&format!("grad_{tag}_d64.fused_speedup"), speedup);

            if loss == Loss::Squared {
                let s_nm_per =
                    bench_batched(&format!("nm_sq_d64 per-block x{n_blocks}"), 2, 30, || {
                        for blk in &per {
                            engine.nm_block(blk, &w).unwrap();
                        }
                        n_blocks
                    });
                println!("{}", s_nm_per.report());
                report.push(&s_nm_per);
                let s_nm_fused =
                    bench_batched(&format!("nm_sq_d64 fused x{n_blocks}"), 2, 30, || {
                        for blk in &batch.groups {
                            engine.nm_block(blk, &w).unwrap();
                        }
                        n_blocks
                    });
                println!("{}", s_nm_fused.report());
                report.push(&s_nm_fused);
                let nm_speedup = s_nm_per.mean_ns / s_nm_fused.mean_ns.max(1.0);
                println!("  -> fused speedup (per 256-row block): {nm_speedup:.2}x");
                report.counter("nm_sq_d64.fused_speedup", nm_speedup);
            }
        }
    }

    section("per-round device traffic (m=4, 4 blocks/machine, d=64)");
    {
        let root = SynthStream::new(SynthSpec::least_squares(64), 7);
        let machines: Vec<MachineBatch> = (0..4)
            .map(|i| {
                let mut s = root.fork_stream(i as u64);
                let samples = s.draw_many(4 * 256);
                MachineBatch::pack(engine, 64, &samples).unwrap()
            })
            .collect();
        let mut net = Network::new(4, NetModel::default());
        let mut meter = ClusterMeter::new(4);
        let w1 = vec![0.02f32; 64];
        println!("{}", DeviceTraffic::header());
        // fresh iterate: exactly one small upload for the whole round
        let t0 = DeviceTraffic::from_stats(&engine.stats);
        distributed_mean_grad(engine, None, Loss::Squared, &machines, &w1, &mut net, &mut meter)
            .unwrap();
        let fresh = DeviceTraffic::from_stats(&engine.stats).since(&t0);
        println!("{}", fresh.row("mean_grad round (new w)"));
        // unchanged iterate: zero uploads, pure cache hits
        let t1 = DeviceTraffic::from_stats(&engine.stats);
        distributed_mean_grad(engine, None, Loss::Squared, &machines, &w1, &mut net, &mut meter)
            .unwrap();
        let warm = DeviceTraffic::from_stats(&engine.stats).since(&t1);
        println!("{}", warm.row("mean_grad round (same w)"));
        report.counter("round.new_w.uploads", fresh.uploads as f64);
        report.counter("round.new_w.downloads", fresh.downloads as f64);
        report.counter("round.same_w.uploads", warm.uploads as f64);
        report.counter("round.same_w.cache_hits", warm.cache_hits as f64);
        // downlink bytes per round: the cross-PR tracking number for the
        // sync (tupled-dispatch) pipeline
        report.counter("round.sync.downlink_bytes", warm.download_bytes as f64);

        // chained pipeline: the same mean-grad round entirely on device —
        // steady-state downlink must be zero (downloads happen only at
        // materialize points, which this round never reaches)
        let w_dev = engine.upload_dev(&w1, &[64]).unwrap();
        let (warmups, iters) = (2usize, 30usize);
        let rounds = (warmups + iters) as f64; // traffic spans warmup too
        let t2 = DeviceTraffic::from_stats(&engine.stats);
        let s_chain = bench("mean_grad round (chained)", warmups, iters, || {
            distributed_mean_grad_dev(
                engine,
                None,
                Loss::Squared,
                &machines,
                &w_dev,
                &mut net,
                &mut meter,
            )
            .unwrap();
        });
        let chained_total = DeviceTraffic::from_stats(&engine.stats).since(&t2);
        println!("{}", s_chain.report());
        report.push_on(&s_chain, "chained");
        let per_round_down = chained_total.download_bytes as f64 / rounds;
        println!("{}", chained_total.row("chained rounds (total)"));
        println!(
            "  -> chained downlink bytes/round: {per_round_down:.1} (sync: {})",
            warm.download_bytes
        );
        report.counter("round.chained.downlink_bytes_per_round", per_round_down);
        report.counter("round.chained.downloads_total", chained_total.downloads as f64);
        report.counter(
            "round.chained.dispatches_per_round",
            chained_total.chained as f64 / rounds,
        );

        // sync vs chained latency for the same round
        let t3 = DeviceTraffic::from_stats(&engine.stats);
        let s_sync = bench("mean_grad round (sync)", warmups, iters, || {
            distributed_mean_grad(
                engine,
                None,
                Loss::Squared,
                &machines,
                &w1,
                &mut net,
                &mut meter,
            )
            .unwrap();
        });
        let sync_total = DeviceTraffic::from_stats(&engine.stats).since(&t3);
        println!("{}", s_sync.report());
        report.push_on(&s_sync, "host");
        report.counter(
            "round.sync.downlink_bytes_per_round",
            sync_total.download_bytes as f64 / rounds,
        );
        report.counter(
            "round.chained_vs_sync_speedup",
            s_sync.mean_ns / s_chain.mean_ns.max(1.0),
        );
    }

    section("host-side costs");
    {
        let mut stream = SynthStream::new(SynthSpec::least_squares(64), 2);
        let samples = stream.draw_many(256);
        let s = bench("pack_block 256x64", 3, 200, || {
            std::hint::black_box(pack_block(&samples, 64));
        });
        println!("{}", s.report());
        report.push(&s);
        let block = pack_block(&samples, 64);
        let s = bench("BlockLits upload 256x64", 3, 200, || {
            std::hint::black_box(BlockLits::from_block(engine, &block).unwrap());
        });
        println!("{}", s.report());
        report.push(&s);
    }

    section("collective round (m=8, d=64)");
    {
        let mut net = Network::new(8, NetModel::default());
        let mut meter = ClusterMeter::new(8);
        let mut locals: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 64]).collect();
        let s = bench("all_reduce_avg m=8 d=64", 10, 500, || {
            net.all_reduce_avg(&mut meter, &mut locals);
        });
        println!("{}", s.report());
        report.push(&s);
    }

    section("end-to-end: one MP-DSVRG outer step (m=4, b=256, d=64)");
    {
        use mbprox::algos::mbprox::MinibatchProx;
        use mbprox::algos::solvers::dsvrg::DsvrgSolver;
        use mbprox::algos::{Method, RunContext};
        use mbprox::objective::Evaluator;
        use mbprox::runtime::ExecPlane;

        let root = SynthStream::new(SynthSpec::least_squares(64), 3);
        let mut eval_stream = root.fork_stream(99);
        let eval_samples = eval_stream.draw_many(512);
        let s = bench("mp-dsvrg outer step (T=1, K=5)", 2, 20, || {
            let streams: Vec<Box<dyn SampleStream>> = (0..4)
                .map(|i| Box::new(root.fork_stream(i as u64)) as Box<dyn SampleStream>)
                .collect();
            let mut plane = ExecPlane::chained(&mut *engine);
            let evaluator =
                Evaluator::new(&mut plane, 64, Loss::Squared, &eval_samples, 4).unwrap();
            let mut ctx = RunContext {
                plane,
                net: Network::new(4, NetModel::default()),
                meter: ClusterMeter::new(4),
                loss: Loss::Squared,
                d: 64,
                streams: mbprox::data::MachineStreams::Local(streams),
                evaluator: Some(evaluator),
                eval_every: 0,
            };
            let mut method =
                MinibatchProx::new("bench", 256, 1, 0.5, DsvrgSolver::new(5, 1, 0.05));
            method.run(&mut ctx).unwrap();
        });
        println!("{}", s.report());
        report.push_on(&s, "chained");
    }

    section("chained all-reduce: m sweep beyond the redm{2,4,8} artifact set");
    {
        // cluster sizes WITH a redm{M} artifact run the device reduce
        // (zero downloads); sizes without one take the host fallback,
        // which must honestly meter one materialize per machine plus the
        // re-upload of the mean
        let d = 64usize;
        let root = SynthStream::new(SynthSpec::least_squares(d), 13);
        for m in [2usize, 4, 6, 8] {
            let machines: Vec<MachineBatch> = (0..m)
                .map(|i| {
                    let mut s = root.fork_stream(100 + i as u64);
                    MachineBatch::pack_grad_only(engine, d, &s.draw_many(256)).unwrap()
                })
                .collect();
            let mut net = Network::new(m, NetModel::default());
            let mut meter = ClusterMeter::new(m);
            let w_host = vec![0.02f32; d];
            let w_dev = engine.upload_dev(&w_host, &[d]).unwrap();
            let served = engine.red_ready(m, d);
            let t0 = DeviceTraffic::from_stats(&engine.stats);
            distributed_mean_grad_dev(
                engine,
                None,
                Loss::Squared,
                &machines,
                &w_dev,
                &mut net,
                &mut meter,
            )
            .unwrap();
            let tr = DeviceTraffic::from_stats(&engine.stats).since(&t0);
            let tag = if served { "served" } else { "fallback" };
            println!("{}", tr.row(&format!("chained mean_grad m={m} (redm {tag})")));
            report.counter(&format!("red.m{m}.served"), served as u64 as f64);
            report.counter(&format!("red.m{m}.downloads"), tr.downloads as f64);
            report.counter(&format!("red.m{m}.download_bytes"), tr.download_bytes as f64);
            if served {
                assert_eq!(
                    tr.downloads, 0,
                    "served reduce (m={m}) must keep the round download-free"
                );
            } else {
                assert!(
                    tr.downloads >= m as u64,
                    "host fallback (m={m}) must meter its per-machine materializes, \
                     got {tr:?}"
                );
            }
        }
    }

    section("shard plane: engine-per-worker speedup (shards=N vs shards=1)");
    {
        use mbprox::algos::mbprox::MinibatchProx;
        use mbprox::algos::solvers::dsvrg::DsvrgSolver;
        use mbprox::algos::Method;
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{default_artifacts_dir, Engine, ShardPool};

        let dir = default_artifacts_dir();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        // N = host cores (capped): on a 1-core host the comparison is
        // recorded but the strict-win assert is skipped (no parallelism
        // exists to measure)
        let n_shards = cores.min(4).max(1);
        let m = 8usize;
        let cfg = ExperimentConfig {
            method: "mp-dsvrg".into(),
            m,
            b_local: 1024,
            n_budget: 2 * 1024 * m, // T = 2 outer steps
            dim: 64,
            seed: 7,
            eval_samples: 256,
            eval_every: 0,
            loss: Loss::Squared,
            ..ExperimentConfig::default()
        };
        let run_once = |r: &mut Runner| {
            let mut ctx = r.context(&cfg).unwrap();
            let mut method =
                MinibatchProx::new("bench", cfg.b_local, 2, 0.5, DsvrgSolver::new(6, 2, 0.05));
            method.run(&mut ctx).unwrap()
        };

        let mut r1 = Runner::new(Engine::new(&dir).unwrap())
            .with_shards(ShardPool::new(1, &dir).unwrap());
        let mut rn = Runner::new(Engine::new(&dir).unwrap())
            .with_shards(ShardPool::new(n_shards, &dir).unwrap());
        // bit-determinism across shard counts, checked in passing
        let w1 = run_once(&mut r1).w;
        let wn = run_once(&mut rn).w;
        assert_eq!(
            w1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            wn.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "shards=1 and shards={n_shards} must produce bit-identical iterates"
        );

        let s1 = bench("mp-dsvrg run (m=8, shards=1)", 1, 5, || {
            run_once(&mut r1);
        });
        println!("{}", s1.report());
        report.push_on(&s1, "sharded");
        let sn = bench(&format!("mp-dsvrg run (m=8, shards={n_shards})"), 1, 5, || {
            run_once(&mut rn);
        });
        println!("{}", sn.report());
        report.push_on(&sn, "sharded");

        let speedup = s1.median_ns / sn.median_ns.max(1.0);
        println!("  -> shard-plane speedup at {n_shards} workers: {speedup:.2}x");
        report.counter("shard.workers", n_shards as f64);
        report.counter("shard.shards1_median_ns", s1.median_ns);
        report.counter("shard.shardsN_median_ns", sn.median_ns);
        report.counter("shard.speedup", speedup);
        // the acceptance criterion: more workers must be a wall-clock win.
        // Medians, not means — one noisy iteration on a shared CI runner
        // must not flip the comparison — and only where parallel hardware
        // exists at all.
        if n_shards > 1 {
            assert!(
                sn.median_ns < s1.median_ns,
                "shards={n_shards} ({:.1}ms) must beat shards=1 ({:.1}ms)",
                sn.median_ns / 1e6,
                s1.median_ns / 1e6
            );
        }

        // cross-shard EngineStats aggregation: the parallel plane's extra
        // join-point traffic is visible, not hidden
        let pooled = rn.shards.as_ref().unwrap().gathered_stats().unwrap();
        let pooled_traffic = DeviceTraffic::from_stats(&pooled);
        println!("{}", pooled_traffic.row(&format!("{n_shards} shard engines (total)")));
        report.counter("shard.pool.uploads", pooled_traffic.uploads as f64);
        report.counter("shard.pool.downloads", pooled_traffic.downloads as f64);
        report.counter("shard.pool.executions", pooled_traffic.executions as f64);
    }

    section("data plane: draw+pack throughput (sequential vs sharded draw)");
    {
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{default_artifacts_dir, Engine, ShardPool};

        let dir = default_artifacts_dir();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let n_shards = cores.min(4).max(1);
        let m = 8usize;
        let b = 2048usize; // 8 blocks per machine per draw
        let cfg = ExperimentConfig {
            method: "minibatch-sgd".into(),
            m,
            b_local: b,
            dim: 64,
            seed: 23,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };

        // sequential draw: coordinator-held streams, packed inline on the
        // coordinator engine (the chained plane)
        let mut r_seq = Runner::new(Engine::new(&dir).unwrap());
        let mut ctx_seq = r_seq.context(&cfg).unwrap();
        let s_seq = bench_batched(&format!("draw+pack b={b} m={m} (sequential)"), 1, 8, || {
            std::hint::black_box(ctx_seq.draw_batches_grad_only(b, false).unwrap());
            m
        });
        println!("{}", s_seq.report());
        report.push_on(&s_seq, "chained");

        // honest peak-memory metering rides the same draw path: one held
        // draw's per-machine peaks land in the report (the paper's
        // memory axis)
        let held = ctx_seq.draw_batches(b, true).unwrap();
        let rep = ctx_seq.meter.report();
        println!(
            "  held draw peak vectors: {} (per machine: {})",
            rep.peak_vectors,
            rep.peaks_display()
        );
        report.counter("draw.held.peak_vectors", rep.peak_vectors as f64);
        ctx_seq.release_batches(&held);
        drop(held);

        // sharded draw: shard-resident streams generate AND pack on the
        // owning shards — no coordinator-side sample materialization
        let mut r_sh = Runner::new(Engine::new(&dir).unwrap())
            .with_shards(ShardPool::new(n_shards, &dir).unwrap());
        let mut ctx_sh = r_sh.context(&cfg).unwrap();
        let s_sh = bench_batched(
            &format!("draw+pack b={b} m={m} (sharded x{n_shards})"),
            1,
            8,
            || {
                std::hint::black_box(ctx_sh.draw_batches_grad_only(b, false).unwrap());
                m
            },
        );
        println!("{}", s_sh.report());
        report.push_on(&s_sh, "sharded");

        let speedup = s_seq.median_ns / s_sh.median_ns.max(1.0);
        println!("  -> sharded draw speedup at {n_shards} workers: {speedup:.2}x");
        report.counter("draw.workers", n_shards as f64);
        report.counter("draw.seq_median_ns", s_seq.median_ns);
        report.counter("draw.sharded_median_ns", s_sh.median_ns);
        report.counter("draw.speedup", speedup);
    }

    section("prefetch lane: dispatch stall (sharded draw, prefetch on vs off)");
    {
        use mbprox::accounting::StallMeter;
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{default_artifacts_dir, Engine, PrefetchPolicy, ShardPool};

        let dir = default_artifacts_dir();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let n_shards = cores.min(4).max(1);
        let m = 8usize;
        let b = 2048usize; // 8 blocks per machine per draw — draw-heavy
        let cfg = ExperimentConfig {
            method: "minibatch-sgd".into(),
            m,
            b_local: b,
            dim: 64,
            seed: 29,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };

        // off: every take draws synchronously inside the lane round-trip,
        // so the worker's full draw+pack time lands in stall_ns. on: the
        // lane pre-packs round t+1 during round t's dispatch, so stall_ns
        // shrinks to the staged-pack handoff.
        let mut measured: Vec<(&str, StallMeter)> = Vec::new();
        for (policy, tag) in [(PrefetchPolicy::Off, "off"), (PrefetchPolicy::On, "on")] {
            let mut r = Runner::new(Engine::new(&dir).unwrap())
                .with_shards(ShardPool::new(n_shards, &dir).unwrap())
                .with_prefetch(policy);
            let mut ctx = r.context(&cfg).unwrap();
            let s = bench_batched(&format!("draw+pack b={b} m={m} (prefetch {tag})"), 1, 6, || {
                std::hint::black_box(ctx.draw_batches_grad_only(b, false).unwrap());
                m
            });
            println!("{}", s.report());
            report.push_on(&s, "sharded");

            let pool = ctx.plane.shards.expect("sharded context");
            let stalls = pool.gathered_stalls().unwrap();
            println!(
                "  prefetch {tag}: {} takes, {} hits, hit rate {:.2}, stalled {:.3} ms",
                stalls.takes,
                stalls.hits,
                stalls.hit_rate(),
                stalls.stall_ns as f64 / 1e6
            );
            report.counter(&format!("prefetch.{tag}.takes"), stalls.takes as f64);
            report.counter(&format!("prefetch.{tag}.hit_rate"), stalls.hit_rate());
            report.counter(&format!("prefetch.{tag}.stall_ns"), stalls.stall_ns as f64);
            // the per-shard breakdown the acceptance criterion asks for
            for (shard, st) in pool.per_shard_stalls().unwrap().iter().enumerate() {
                let key = format!("prefetch.{tag}.shard{shard}.stall_ns");
                report.counter(&key, st.stall_ns as f64);
            }
            measured.push((tag, stalls));
        }

        let off = &measured[0].1;
        let on = &measured[1].1;
        // off must never be served from a stage; on is cold only on each
        // machine's first take
        assert_eq!(off.hits, 0, "prefetch=off must not report stage hits");
        // each machine's first take is a cold miss by construction; later
        // takes hit whenever the lane finished its refill first. >= 0.5
        // rather than the exact (takes - m) / takes: under pathological
        // scheduling a refill can still be in flight when the next take
        // lands, which is a legitimate (rare) miss, not a bug. On a
        // 1-core host the lane may never win the race, so (like the
        // stall win below) the assert needs real parallelism to exist.
        if cores > 1 {
            assert!(
                on.hit_rate() >= 0.5,
                "prefetch=on hit rate collapsed: {} hits / {} takes",
                on.hits,
                on.takes
            );
        }
        let reduction = off.stall_ns as f64 / (on.stall_ns as f64).max(1.0);
        println!("  -> dispatch-stall reduction with prefetch on: {reduction:.2}x");
        report.counter("prefetch.stall_reduction", reduction);
        // the acceptance criterion: overlap must be a wall-clock win on
        // the dispatch path — wherever a second core exists to overlap on
        if cores > 1 {
            assert!(
                on.stall_ns < off.stall_ns,
                "prefetch on ({:.1}ms stalled) must beat off ({:.1}ms stalled)",
                on.stall_ns as f64 / 1e6,
                off.stall_ns as f64 / 1e6
            );
        }
    }

    section("pipelined shard dispatch (batched fans, pipeline on vs off)");
    {
        use mbprox::accounting::OverlapMeter;
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{
            default_artifacts_dir, Engine, PipelinePolicy, PrefetchPolicy, ShardPool,
        };
        use mbprox::util::benchkit::BenchStats;

        let dir = default_artifacts_dir();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let n_shards = cores.min(4).max(1);
        let m = 8usize;
        let b = 2048usize; // 8 blocks per machine per draw — pack-heavy
        let cfg = ExperimentConfig {
            method: "minibatch-sgd".into(),
            m,
            b_local: b,
            dim: 64,
            seed: 31,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };

        // both legs run with prefetch OFF so the only overlap in play is
        // the fan pipeline's own (pack machine k+1's lane draw while
        // machine k's dispatch is still in flight). off: every pack runs
        // with an empty ticket window, so overlap_ns stays zero. on: with
        // >= 2 machines per shard (m=8, <= 4 shards) every non-final pack
        // runs staged.
        let mut measured: Vec<(&str, OverlapMeter, BenchStats)> = Vec::new();
        for (policy, tag) in [(PipelinePolicy::Off, "off"), (PipelinePolicy::On, "on")] {
            let mut r = Runner::new(Engine::new(&dir).unwrap())
                .with_shards(ShardPool::new(n_shards, &dir).unwrap())
                .with_prefetch(PrefetchPolicy::Off)
                .with_pipeline(policy);
            let mut ctx = r.context(&cfg).unwrap();
            let s = bench_batched(&format!("draw+pack b={b} m={m} (pipeline {tag})"), 1, 6, || {
                std::hint::black_box(ctx.draw_batches_grad_only(b, false).unwrap());
                m
            });
            println!("{}", s.report());
            report.push_on(&s, "sharded");

            let pool = ctx.plane.shards.expect("sharded context");
            let overlap = pool.gathered_overlap().unwrap();
            println!(
                "  pipeline {tag}: {} fans, {} staged packs, overlap {:.3} ms, \
                 serial {:.3} ms ({:.0}% overlapped)",
                overlap.fans,
                overlap.staged,
                overlap.overlap_ns as f64 / 1e6,
                overlap.serial_ns as f64 / 1e6,
                overlap.overlap_frac() * 100.0
            );
            report.counter(&format!("pipeline.{tag}.fans"), overlap.fans as f64);
            report.counter(&format!("pipeline.{tag}.staged"), overlap.staged as f64);
            report.counter(&format!("pipeline.{tag}.overlap_ns"), overlap.overlap_ns as f64);
            report.counter(&format!("pipeline.{tag}.serial_ns"), overlap.serial_ns as f64);
            report.counter(&format!("pipeline.{tag}.overlap_frac"), overlap.overlap_frac());
            // the per-shard breakdown the acceptance criterion asks for
            for (shard, o) in pool.per_shard_overlap().unwrap().iter().enumerate() {
                let key = format!("pipeline.{tag}.shard{shard}.overlap_ns");
                report.counter(&key, o.overlap_ns as f64);
            }
            measured.push((tag, overlap, s));
        }

        let (off, s_off) = (&measured[0].1, &measured[0].2);
        let (on, s_on) = (&measured[1].1, &measured[1].2);
        // honesty: the serial path must never claim overlapped work, and
        // the pipelined path must always stage (>= 2 machines per shard
        // by construction, so every fan has at least one non-final pack).
        // Neither assert needs a second core — staging is a property of
        // the dispatch order, not of wall-clock parallelism.
        assert_eq!(off.staged, 0, "pipeline=off must not stage packs");
        assert_eq!(off.overlap_ns, 0, "pipeline=off must not report overlapped work");
        assert!(on.staged >= 1, "pipeline=on staged no packs: {on:?}");
        assert!(on.overlap_ns >= 1, "pipeline=on overlapped no work: {on:?}");
        // fan count is policy-independent: batching is unconditional
        assert_eq!(off.fans, on.fans, "fan count must not depend on the pipeline policy");

        let speedup = s_off.median_ns / s_on.median_ns.max(1.0);
        println!("  -> pipelined dispatch speedup at {n_shards} workers: {speedup:.2}x");
        report.counter("pipeline.speedup", speedup);
        // the acceptance criterion: pipelining must be a wall-clock win on
        // the dispatch path — wherever a second core exists for the lane
        // to draw on while the worker packs. Medians, not means, for the
        // same shared-CI-runner reason as the shard-plane assert above.
        if cores > 1 {
            assert!(
                s_on.median_ns < s_off.median_ns,
                "pipeline on ({:.1}ms) must beat off ({:.1}ms)",
                s_on.median_ns / 1e6,
                s_off.median_ns / 1e6
            );
        }
    }

    section("upload lane: staging rings on the hot path (upload on vs off)");
    {
        use mbprox::accounting::UploadMeter;
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{default_artifacts_dir, Engine, ShardPool, UploadPolicy};

        let dir = default_artifacts_dir();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let n_shards = cores.min(4).max(1);
        let m = 8usize;
        let cfg = ExperimentConfig {
            method: "mp-dsvrg".into(),
            m,
            b_local: 256,
            n_budget: 4 * 256 * m, // T = 4 outer steps, fresh w each round
            dim: 64,
            seed: 41,
            eval_samples: 64,
            eval_every: 0,
            loss: Loss::Squared,
            ..ExperimentConfig::default()
        };

        // off: every pooled operand goes through the single-slot session
        // path. on: operands stage into the back ring half and swap at
        // the dispatch boundary. The meters are wall-clock diagnostics —
        // upload COUNTS and BYTES must be bit-identical either way (the
        // ring compares against the active half exactly like the slot
        // path compares against its last payload).
        let mut measured: Vec<(&str, UploadMeter, Vec<u32>)> = Vec::new();
        for (policy, tag) in [(UploadPolicy::Off, "off"), (UploadPolicy::On, "on")] {
            let mut r = Runner::new(Engine::new(&dir).unwrap())
                .with_shards(ShardPool::new(n_shards, &dir).unwrap())
                .with_upload(policy);
            let res = r.run(&cfg).unwrap();
            let s = bench(&format!("mp-dsvrg run (m=8, upload {tag})"), 1, 5, || {
                r.run(&cfg).unwrap();
            });
            println!("{}", s.report());
            report.push_on(&s, "sharded");

            let u = res.uploads.clone().expect("upload meter is present on every plane");
            println!(
                "  upload {tag}: {} uploads ({} B), {} staged, {:.3} ms overlappable, \
                 {:.3} ms waited at the swap boundary",
                u.uploads,
                u.bytes,
                u.staged,
                u.overlap_ns as f64 / 1e6,
                u.wait_ns as f64 / 1e6
            );
            report.counter(&format!("upload.{tag}.uploads"), u.uploads as f64);
            report.counter(&format!("upload.{tag}.staged"), u.staged as f64);
            report.counter(&format!("upload.{tag}.overlap_ns"), u.overlap_ns as f64);
            report.counter(&format!("upload.{tag}.wait_ns"), u.wait_ns as f64);
            report.counter(&format!("upload.{tag}.bytes"), u.bytes as f64);
            let bits = res.w.iter().map(|x| x.to_bits()).collect();
            measured.push((tag, u, bits));
        }

        let (off, w_off) = (&measured[0].1, &measured[0].2);
        let (on, w_on) = (&measured[1].1, &measured[1].2);
        // parity: the lane must not change the math
        assert_eq!(w_off, w_on, "upload lane must not change the iterate bits");
        // honesty: the slot path never claims staged transfers; the lane
        // must actually stage on this fresh-w-per-round workload. Neither
        // assert needs a second core — staging is a property of the
        // dispatch order, not of wall-clock parallelism.
        assert_eq!(off.staged, 0, "upload=off must not stage: {off:?}");
        assert_eq!(off.overlap_ns, 0, "upload=off must not claim overlappable time: {off:?}");
        assert!(on.uploads >= 1, "upload=on moved nothing: {on:?}");
        assert!(on.staged >= 1, "upload=on staged nothing: {on:?}");
        assert!(on.overlap_ns >= 1, "upload=on overlapped no transfer time: {on:?}");
        // traffic parity: counts and bytes identical with the lane on/off
        assert_eq!(off.uploads, on.uploads, "upload counts must not depend on the policy");
        assert_eq!(off.bytes, on.bytes, "upload bytes must not depend on the policy");
        report.counter("upload.bytes_equal", (off.bytes == on.bytes) as u64 as f64);
    }

    section("fault injection: degradation under stragglers (mp-dsvrg vs minibatch-SGD)");
    {
        use mbprox::comm::faults::FaultsPolicy;
        use mbprox::config::ExperimentConfig;
        use mbprox::runtime::{default_artifacts_dir, Engine};

        let dir = default_artifacts_dir();
        // fresh chained runner (no pool): the fault schedule is drawn
        // coordinator-side at each collective's network charge, so the
        // shard plane adds nothing to this measurement and the runs stay
        // fast. Every counter below is SIMULATED and seed-deterministic —
        // bounded in BENCH_baseline.json, not wall-clock noise.
        let mut r = Runner::new(Engine::new(&dir).unwrap());
        let base = ExperimentConfig {
            m: 4,
            b_local: 256,
            n_budget: 4096,
            dim: 64,
            seed: 37,
            eval_samples: 64,
            eval_every: 0,
            loss: Loss::Squared,
            faults: FaultsPolicy::On,
            slowdown_alpha: Some(1.5),
            ..ExperimentConfig::default()
        };
        let mut p50_added: Vec<(&str, f64)> = Vec::new();
        for (method, mtag) in [("mp-dsvrg", "mbprox"), ("minibatch-sgd", "sgd")] {
            let mut added = Vec::new();
            let mut sims = Vec::new();
            for (p, ptag) in [(0.0, "p0"), (0.2, "p20"), (0.5, "p50")] {
                let cfg = ExperimentConfig {
                    method: method.into(),
                    straggler_p: Some(p),
                    ..base.clone()
                };
                let res = r.run(&cfg).unwrap();
                let fm = res.faults.clone().expect("faults=on must surface a meter");
                println!(
                    "  {method} straggler_p={p}: {} stragglers over {} slow rounds, \
                     +{:.5} s on {:.5} s simulated",
                    fm.stragglers, fm.slow_rounds, fm.added_time_s, res.sim_time_s
                );
                report.counter(&format!("faults.{mtag}.{ptag}.stragglers"), fm.stragglers as f64);
                report.counter(&format!("faults.{mtag}.{ptag}.added_s"), fm.added_time_s);
                added.push(fm.added_time_s);
                sims.push(res.sim_time_s);
            }
            // the per-(round,machine) fault rng is pure, so raising p only
            // ADDS straggler events (the shared events keep identical
            // Pareto draws): severity is monotone by construction
            assert!(
                added[0] == 0.0 && added[1] <= added[2],
                "straggler cost must be monotone in p for {method}: {added:?}"
            );
            let degradation = sims[2] / sims[0];
            assert!(
                degradation >= 1.0,
                "straggling must never make {method} faster: {degradation}"
            );
            println!("  -> {method} sim-time degradation at p=0.5: {degradation:.3}x");
            report.counter(&format!("faults.{mtag}.degradation"), degradation);
            p50_added.push((mtag, added[2]));
        }
        // cross-method shape (recorded, not bounded: the two methods run
        // different round counts at the same budget, so neither direction
        // is guaranteed): minibatch-prox's fewer, heavier rounds expose
        // less straggler surface per sample than SGD's many light ones
        let ratio = p50_added[1].1 / p50_added[0].1.max(f64::MIN_POSITIVE);
        println!("  -> straggler cost ratio sgd/mbprox at p=0.5: {ratio:.3}");
        report.counter("faults.added_ratio_sgd_over_mbprox", ratio);

        // dropout: machines leave for whole windows and re-enter at a
        // collective boundary; survivors carry the dropped share (the
        // m/(m-k) redistribution factor) as added simulated time
        let cfg_drop = ExperimentConfig {
            method: "minibatch-sgd".into(),
            straggler_p: Some(0.0),
            dropout_p: Some(0.5),
            dropout_rounds: Some(2),
            ..base.clone()
        };
        let res_a = r.run(&cfg_drop).unwrap();
        let res_b = r.run(&cfg_drop).unwrap();
        let fa = res_a.faults.clone().expect("faults=on must surface a meter");
        println!(
            "  dropout_p=0.5: {} dropouts, {} machine-rounds out, {} re-entries, +{:.5} s",
            fa.dropouts, fa.dropped_rounds, fa.reentries, fa.added_time_s
        );
        assert!(
            fa.dropouts >= 1 && fa.reentries >= 1,
            "seeded dropout run produced no dropout/re-entry cycle: {fa:?}"
        );
        // seeded reproducibility: the whole schedule and its cost are a
        // pure function of (seed, m, params) — bit-equal across runs
        assert_eq!(res_a.faults, res_b.faults, "fault schedule must be seed-deterministic");
        assert_eq!(
            res_a.sim_time_s.to_bits(),
            res_b.sim_time_s.to_bits(),
            "faulted sim time must be bit-reproducible"
        );
        report.counter("faults.dropout.dropouts", fa.dropouts as f64);
        report.counter("faults.dropout.reentries", fa.reentries as f64);
    }

    section("serve: concurrent clients (warm pool, bounded queue)");
    {
        use mbprox::config::ServeConfig;
        use mbprox::runtime::default_artifacts_dir;
        use mbprox::serve::{http_get, http_post, Server};
        use mbprox::util::json::Json;
        use std::time::Instant;

        let cfg = ServeConfig { port: 0, queue_depth: 64, ..ServeConfig::default() };
        let server = Server::bind(&cfg, &default_artifacts_dir()).expect("bind serve port 0");
        let addr = server.addr();
        let server_thread = std::thread::spawn(move || server.run().expect("server run"));

        let body = "method = mp-dsvrg\nscenario = drift\nloss = sq\nm = 4\nb_local = 256\n\
                    n_budget = 2048\ndim = 64\nseed = 4242\neval_samples = 256\n\
                    eval_every = 0\n";

        // cold job: the resident runner is built and every artifact
        // compiles — the queue-to-done latency the cache exists to cut
        let (cold_lat, cold) = serve_post_timed(addr, body);
        println!(
            "  cold job: {:.1} ms queue-to-done, {} compiles ({:.1} ms compile)",
            cold_lat / 1e6,
            cold.misses,
            cold.compile_ns as f64 / 1e6
        );
        assert!(cold.misses >= 1, "cold job must compile: {cold:?}");
        assert_eq!(cold.hits, 0, "nothing is warm on the cold job: {cold:?}");
        report.counter("serve.cold.misses", cold.misses as f64);
        report.counter("serve.cold.compile_ns", cold.compile_ns as f64);
        report.counter("serve.cold.latency_ns", cold_lat);

        // warm phase: N concurrent clients hammer the same config; every
        // job rides the hot cache (hit_rate 1.0, zero compiles) and the
        // bounded queue serializes them onto the one warm pool
        let clients = 4usize;
        let per_client = 3usize;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let body = body.to_string();
                std::thread::spawn(move || {
                    (0..per_client)
                        .map(|_| serve_post_timed(addr, &body))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let warm: Vec<(f64, mbprox::accounting::CacheMeter)> = handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect();
        let wall_s = t0.elapsed().as_secs_f64();
        let jobs = warm.len();

        let mut lats: Vec<f64> = warm.iter().map(|(l, _)| *l).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&lats, 0.50);
        let p99 = percentile(&lats, 0.99);
        let runs_per_sec = jobs as f64 / wall_s.max(f64::MIN_POSITIVE);
        let warm_misses: u64 = warm.iter().map(|(_, c)| c.misses).sum();
        let warm_compile: u64 = warm.iter().map(|(_, c)| c.compile_ns).sum();
        let min_hit_rate = warm
            .iter()
            .map(|(_, c)| c.hit_rate())
            .fold(f64::INFINITY, f64::min);
        println!(
            "  warm phase: {jobs} jobs from {clients} clients in {wall_s:.2} s \
             ({runs_per_sec:.1} runs/s), p50 {:.1} ms, p99 {:.1} ms",
            p50 / 1e6,
            p99 / 1e6
        );
        // after the first job the cache is complete: every warm job is
        // all hits (hit_rate exactly 1.0), no compiles, no compile time
        assert_eq!(warm_misses, 0, "warm jobs must not recompile");
        assert_eq!(min_hit_rate, 1.0, "warm hit rate must be exactly 1.0");
        report.counter("serve.clients", clients as f64);
        report.counter("serve.jobs", jobs as f64);
        report.counter("serve.runs_per_sec", runs_per_sec);
        report.counter("serve.p50_ns", p50);
        report.counter("serve.p99_ns", p99);
        report.counter("serve.warm.misses", warm_misses as f64);
        report.counter("serve.warm.hit_rate", min_hit_rate);
        report.counter("serve.warm.compile_ns", warm_compile as f64);
        // the amortization headline: compile time paid cold vs warm
        let ratio = cold.compile_ns as f64 / (warm_compile as f64).max(1.0);
        println!("  -> cold-over-warm compile-time ratio: {ratio:.0}x");
        report.counter("serve.cold_over_warm_compile_ns", ratio);

        let (status, stats_body) = http_get(addr, "/stats").expect("GET /stats");
        assert_eq!(status, 200);
        let v = Json::parse(&stats_body).expect("stats json");
        let done = v.get("jobs_done").and_then(Json::as_f64).unwrap_or(0.0);
        assert_eq!(done as usize, jobs + 1, "every job completed: {stats_body}");
        report.counter(
            "serve.rejected",
            v.get("jobs_rejected").and_then(Json::as_f64).unwrap_or(-1.0),
        );

        let (status, _) = http_post(addr, "/shutdown", "").expect("POST /shutdown");
        assert_eq!(status, 200);
        let final_stats = server_thread.join().expect("server thread");
        assert_eq!(final_stats.jobs_rejected, 0, "depth-64 queue must not reject this load");
    }

    section("engine cumulative stats");
    let traffic = DeviceTraffic::from_stats(&engine.stats);
    println!("{}", DeviceTraffic::header());
    println!("{}", traffic.row("total"));
    println!(
        "executions={} mean_execute={} bytes_moved={}",
        engine.stats.executions,
        mbprox::util::benchkit::fmt_ns(engine.mean_execute_ns()),
        engine.stats.bytes_moved(),
    );
    report.counter("engine.executions", engine.stats.executions as f64);
    report.counter("engine.mean_execute_ns", engine.mean_execute_ns());
    report.counter("engine.uploads", traffic.uploads as f64);
    report.counter("engine.upload_bytes", traffic.upload_bytes as f64);
    report.counter("engine.downloads", traffic.downloads as f64);
    report.counter("engine.download_bytes", traffic.download_bytes as f64);
    report.counter("engine.upload_cache_hits", traffic.cache_hits as f64);
    report.counter("engine.upload_cache_misses", traffic.cache_misses as f64);
    report.write("BENCH_runtime.json").expect("write BENCH_runtime.json");
    println!("\nwrote BENCH_runtime.json");
}
