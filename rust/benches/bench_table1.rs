//! Bench: Table 1 — per-machine resources for every method at a fixed
//! sample budget (reduced n for bench speed; the full-size run is
//! `cargo run --release --example table1_resources`).
//!
//! Prints measured comm rounds / vec ops / peak memory / wall time so the
//! Table-1 orderings (who wins on which resource) are regenerated on every
//! `cargo bench`.

use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::util::benchkit;
use std::time::Instant;

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let base = ExperimentConfig {
        m: 4,
        n_budget: 16_384,
        loss: Loss::Squared,
        dim: 64,
        seed: 3,
        eval_samples: 2048,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    benchkit::section("Table 1: measured per-machine resources (n=16384, m=4)");
    println!(
        "{:<28} {:>10} {:>12} {:>12} {:>10} {:>12} {:>10}",
        "method", "b_local", "comm_rounds", "vec_ops", "memory", "objective", "wall"
    );
    let rows: Vec<(&str, &str, usize, usize)> = vec![
        ("Ideal (local SGD)", "local-sgd", 256, 1),
        ("Acc. minibatch SGD", "acc-minibatch-sgd", 64, 4),
        ("Minibatch SGD", "minibatch-sgd", 64, 4),
        ("DANE (ERM)", "dane-erm", 256, 4),
        ("DiSCO (ERM)", "disco-erm", 256, 4),
        ("AGD (ERM)", "agd-erm", 256, 4),
        ("DSVRG (ERM)", "dsvrg-erm", 256, 4),
        ("MP-DSVRG (b=256)", "mp-dsvrg", 256, 4),
        ("MP-DSVRG (b=b_max)", "mp-dsvrg", 4096, 4),
        ("MP-DANE (b=256)", "mp-dane", 256, 4),
    ];
    for (label, method, b, m) in rows {
        let cfg = ExperimentConfig {
            method: method.to_string(),
            b_local: b,
            m,
            ..base.clone()
        };
        let t0 = Instant::now();
        match runner.run(&cfg) {
            Ok(r) => println!(
                "{:<28} {:>10} {:>12} {:>12} {:>10} {:>12} {:>10}",
                label,
                b,
                r.report.comm_rounds,
                r.report.vec_ops,
                r.report.peak_vectors,
                r.final_objective.map(|o| format!("{o:.5}")).unwrap_or_default(),
                benchkit::fmt_ns(t0.elapsed().as_nanos() as f64)
            ),
            Err(e) => println!("{label:<28} ERROR: {e}"),
        }
    }
}
