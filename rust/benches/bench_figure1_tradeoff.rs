//! Bench: Figure 1 — MP-DSVRG's communication/memory tradeoff as b sweeps
//! a log grid up to b_max = n/m. The paper's claim: communication falls as
//! n/(mb) (log factors aside) while memory rises as b, with computation
//! flat — verified as measured ratios between successive b values.

use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::util::benchkit;

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let n_budget = 16_384usize;
    let m = 4usize;
    benchkit::section("Figure 1: MP-DSVRG communication-memory tradeoff (n=16384, m=4)");
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "b", "comm_rounds", "vec_ops", "memory", "objective", "comm*mem"
    );
    let mut prev: Option<(u64, u64)> = None;
    let mut b = 64usize;
    while b <= n_budget / m {
        let cfg = ExperimentConfig {
            method: "mp-dsvrg".into(),
            b_local: b,
            m,
            n_budget,
            loss: Loss::Squared,
            dim: 64,
            seed: 5,
            eval_samples: 2048,
            eval_every: 0,
            ..ExperimentConfig::default()
        };
        match runner.run(&cfg) {
            Ok(r) => {
                println!(
                    "{:>8} {:>12} {:>12} {:>10} {:>12} {:>12}",
                    b,
                    r.report.comm_rounds,
                    r.report.vec_ops,
                    r.report.peak_vectors,
                    r.final_objective.map(|o| format!("{o:.5}")).unwrap_or_default(),
                    r.report.comm_rounds * r.report.peak_vectors
                );
                if let Some((pc, pm)) = prev {
                    let comm_ratio = pc as f64 / r.report.comm_rounds.max(1) as f64;
                    let mem_ratio = r.report.peak_vectors as f64 / pm.max(1) as f64;
                    println!(
                        "         ^ 4x b => comm fell {comm_ratio:.1}x, memory rose {mem_ratio:.1}x"
                    );
                }
                prev = Some((r.report.comm_rounds, r.report.peak_vectors));
            }
            Err(e) => println!("b={b}: ERROR {e}"),
        }
        b *= 4;
    }
}
