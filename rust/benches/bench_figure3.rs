//! Bench: Figure 3 (reduced slice) — MP-DANE vs minibatch SGD objective
//! vs minibatch size on one Table-3-like dataset. The full grid is
//! `cargo run --release --example figure3_convergence`.
//!
//! The two claims regenerated here:
//!   1. minibatch SGD's objective degrades sharply as b grows;
//!   2. MP-DANE's objective degrades slowly, and more DANE rounds K help
//!      with diminishing returns.

use mbprox::algos::mbprox::MinibatchProx;
use mbprox::algos::minibatch_sgd::MinibatchSgd;
use mbprox::algos::solvers::dane::DaneSolver;
use mbprox::algos::Method;
use mbprox::coordinator::Runner;
use mbprox::data::sampler::{shard_ranges, VecStream};
use mbprox::data::table3::CODRNA;
use mbprox::data::{Loss, Sample, SampleStream};
use mbprox::theory::{self, ProblemConsts};
use mbprox::util::benchkit;
use mbprox::util::prng::Prng;

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let spec = &CODRNA;
    let n_train = 4096usize;
    let m = 4usize;
    let mut stream = spec.stream(42);
    let all = stream.draw_many(n_train + 2048);
    let (train, eval) = all.split_at(n_train);

    benchkit::section(&format!(
        "Figure 3 slice: {} (n_train={n_train}, m={m}, logistic)",
        spec.name
    ));
    println!("{:<18} {:>4} {:>8} {:>12} {:>12}", "method", "K", "b", "objective", "rounds");

    let consts = ProblemConsts {
        l_lipschitz: 1.0,
        b_norm: 2.0 * (spec.dim as f64).sqrt(),
        beta_smooth: 0.25,
        m,
    };
    for &b in &[64usize, 256, 1024] {
        if b * m > n_train {
            continue;
        }
        let plan = theory::mbprox_plan(&consts, n_train as f64, b);
        for &k in &[1usize, 4] {
            let eta = 0.1 / (consts.beta_smooth + plan.gamma);
            let mut method = MinibatchProx::new(
                "mp-dane",
                b,
                plan.t_outer,
                plan.gamma,
                DaneSolver::plain(k, eta),
            );
            let (obj, rounds) = run(&mut runner, train, eval, m, &mut method);
            println!("{:<18} {:>4} {:>8} {:>12.5} {:>12}", "mp-dane", k, b, obj, rounds);
        }
        let gamma = theory::minibatch_sgd_gamma(&consts, plan.t_outer, plan.bm);
        let mut sgd = MinibatchSgd { b_local: b, t_outer: plan.t_outer, gamma };
        let (obj, rounds) = run(&mut runner, train, eval, m, &mut sgd);
        println!("{:<18} {:>4} {:>8} {:>12.5} {:>12}", "minibatch-sgd", 0, b, obj, rounds);
    }
}

fn run(
    runner: &mut Runner,
    train: &[Sample],
    eval: &[Sample],
    m: usize,
    method: &mut dyn Method,
) -> (f64, u64) {
    let d = runner.engine.manifest().padded_dim(train[0].x.len()).unwrap();
    let ranges = shard_ranges(train.len(), m);
    let root = Prng::seed_from_u64(77);
    let streams: Vec<Box<dyn SampleStream>> = (0..m)
        .map(|i| {
            Box::new(VecStream::new(
                train[ranges[i].clone()].to_vec(),
                Loss::Logistic,
                root.split(i as u64),
            )) as Box<dyn SampleStream>
        })
        .collect();
    let mut ctx = runner.context_over(Loss::Logistic, d, streams, eval, 0).unwrap();
    let r = method.run(&mut ctx).expect("run failed");
    (r.final_objective.unwrap_or(f64::NAN), r.report.comm_rounds)
}
