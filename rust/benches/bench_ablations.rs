//! Bench: ablations on the design choices DESIGN.md calls out.
//!
//!   A1. Local VR solver: SVRG vs SAGA inside MP-DANE (the paper's App. E
//!       uses SAGA; our default is SVRG — same kernel interface).
//!   A2. DANE rounds K: the diminishing-returns claim at fixed budget.
//!   A3. SVRG stepsize eta sensitivity around the 0.1/(beta+gamma) rule.
//!   A4. DSVRG local batches p: theory picks p ~ b/kappa; sweep around it.

use mbprox::algos::mbprox::MinibatchProx;
use mbprox::algos::solvers::dane::DaneSolver;
use mbprox::algos::solvers::dsvrg::DsvrgSolver;
use mbprox::algos::solvers::LocalSolver;
use mbprox::algos::Method;
use mbprox::coordinator::Runner;
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::theory::{self, ProblemConsts};
use mbprox::util::benchkit;

const N: usize = 16_384;
const M: usize = 4;
const B: usize = 256;
const DIM: usize = 64;

fn run(runner: &mut Runner, method: &mut dyn Method, seed: u64) -> (f64, u64, u64) {
    let root = SynthStream::new(SynthSpec::least_squares(DIM), seed);
    let streams: Vec<Box<dyn SampleStream>> = (0..M)
        .map(|i| Box::new(root.fork_stream(i as u64)) as Box<dyn SampleStream>)
        .collect();
    let mut eval_stream = root.fork_stream(4242);
    let eval_samples = eval_stream.draw_many(2048);
    let mut ctx = runner.context_over(Loss::Squared, DIM, streams, &eval_samples, 0).unwrap();
    let r = method.run(&mut ctx).unwrap();
    (r.final_objective.unwrap_or(f64::NAN), r.report.comm_rounds, r.report.vec_ops)
}

fn consts() -> (ProblemConsts, theory::MbProxPlan) {
    let c = ProblemConsts {
        l_lipschitz: 1.0,
        b_norm: (DIM as f64).sqrt(),
        beta_smooth: 1.0,
        m: M,
    };
    let plan = theory::mbprox_plan(&c, N as f64, B);
    (c, plan)
}

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let (c, plan) = consts();
    let eta = 0.1 / (c.beta_smooth + plan.gamma);

    benchkit::section("A1: MP-DANE local solver — SVRG vs SAGA (paper App. E uses SAGA)");
    println!("{:<10} {:>12} {:>12} {:>12}", "solver", "objective", "rounds", "vec_ops");
    for solver in [LocalSolver::Svrg, LocalSolver::Saga] {
        let mut m = MinibatchProx::new(
            "mp-dane",
            B,
            plan.t_outer,
            plan.gamma,
            DaneSolver::plain(6, eta).with_local_solver(solver),
        );
        let (obj, rounds, ops) = run(&mut runner, &mut m, 1);
        println!("{:<10} {:>12.5} {:>12} {:>12}", solver.tag(), obj, rounds, ops);
    }

    benchkit::section("A2: DANE rounds K — diminishing returns at fixed sample budget");
    println!("{:<6} {:>12} {:>12} {:>12}", "K", "objective", "rounds", "vec_ops");
    for k in [1usize, 2, 4, 8, 16] {
        let mut m = MinibatchProx::new(
            "mp-dane",
            B,
            plan.t_outer,
            plan.gamma,
            DaneSolver::plain(k, eta),
        );
        let (obj, rounds, ops) = run(&mut runner, &mut m, 2);
        println!("{:<6} {:>12.5} {:>12} {:>12}", k, obj, rounds, ops);
    }

    benchkit::section("A3: SVRG stepsize eta around the 0.1/(beta+gamma) rule");
    println!("{:<10} {:>12}", "eta_scale", "objective");
    for scale in [0.25f64, 0.5, 1.0, 2.0, 4.0] {
        let mut m = MinibatchProx::new(
            "mp-dsvrg",
            B,
            plan.t_outer,
            plan.gamma,
            DsvrgSolver::new(8, 1, eta * scale),
        );
        let (obj, _, _) = run(&mut runner, &mut m, 3);
        println!("{:<10} {:>12.5}", format!("{scale}x"), obj);
    }

    benchkit::section("A4: DSVRG local batches p (theory: p ~ b / condition-number)");
    println!("{:<6} {:>12} {:>12}", "p", "objective", "rounds");
    for p in [1usize, 2, 4, 8] {
        let mut m = MinibatchProx::new(
            "mp-dsvrg",
            1024, // 4 blocks per machine so p actually splits them
            theory::mbprox_plan(&c, N as f64, 1024).t_outer,
            theory::mbprox_plan(&c, N as f64, 1024).gamma,
            DsvrgSolver::new(8, p, eta),
        );
        let (obj, rounds, _) = run(&mut runner, &mut m, 4);
        println!("{:<6} {:>12.5} {:>12}", p, obj, rounds);
    }
}
