//! Bench: Figure 2 — communication / computation / memory vs minibatch
//! size for the full method roster (MP-DSVRG, MP-DANE, acc-minibatch-SGD,
//! minibatch SGD) plus the ERM batch methods as right-edge reference
//! points (DSVRG / DANE / DiSCO at b = n/m).

use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::util::benchkit;

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let n_budget = 16_384usize;
    let m = 4usize;
    let base = ExperimentConfig {
        m,
        n_budget,
        loss: Loss::Squared,
        dim: 64,
        seed: 11,
        eval_samples: 2048,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    benchkit::section("Figure 2: comm/comp/mem vs b, all methods (n=16384, m=4)");
    println!(
        "{:<20} {:>8} {:>12} {:>12} {:>10} {:>12}",
        "method", "b", "comm_rounds", "vec_ops", "memory", "objective"
    );
    for method in ["mp-dsvrg", "mp-dane", "acc-minibatch-sgd", "minibatch-sgd"] {
        let mut b = 64usize;
        while b <= n_budget / m {
            let cfg = ExperimentConfig {
                method: method.to_string(),
                b_local: b,
                ..base.clone()
            };
            match runner.run(&cfg) {
                Ok(r) => println!(
                    "{:<20} {:>8} {:>12} {:>12} {:>10} {:>12}",
                    method,
                    b,
                    r.report.comm_rounds,
                    r.report.vec_ops,
                    r.report.peak_vectors,
                    r.final_objective.map(|o| format!("{o:.5}")).unwrap_or_default()
                ),
                Err(e) => println!("{method} b={b}: ERROR {e}"),
            }
            b *= 4;
        }
    }
    println!("-- batch (ERM) reference points at b = n/m --");
    for method in ["dsvrg-erm", "dane-erm", "disco-erm", "agd-erm"] {
        let cfg = ExperimentConfig { method: method.to_string(), ..base.clone() };
        match runner.run(&cfg) {
            Ok(r) => println!(
                "{:<20} {:>8} {:>12} {:>12} {:>10} {:>12}",
                method,
                n_budget / m,
                r.report.comm_rounds,
                r.report.vec_ops,
                r.report.peak_vectors,
                r.final_objective.map(|o| format!("{o:.5}")).unwrap_or_default()
            ),
            Err(e) => println!("{method}: ERROR {e}"),
        }
    }
}
