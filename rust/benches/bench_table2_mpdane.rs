//! Bench: Table 2 — MP-DANE's two resource regimes around b*.
//!
//! Runs MP-DANE with the plain (kappa = 0, R = 1) solver below b* and the
//! AIDE-accelerated solver above it, reporting the three Table-2 resource
//! rows. b* is computed from the theory with the paper's O(1)-norm
//! convention (B = 1) so both regimes are reachable at bench scale; the
//! data-scale plans are exercised by the coordinator tests.

use mbprox::algos::mbprox::MinibatchProx;
use mbprox::algos::solvers::dane::DaneSolver;
use mbprox::algos::Method;
use mbprox::coordinator::Runner;
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::theory::{self, ProblemConsts};
use mbprox::util::benchkit;

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    let n = 8_192usize;
    let m = 4usize;
    let dim = 64usize;
    // norm convention scaled so b* lands mid-grid at bench scale
    // (b* ~ 1/B^2; the data-scale plans are exercised in coordinator tests)
    let consts = ProblemConsts { l_lipschitz: 1.0, b_norm: 0.12, beta_smooth: 1.0, m };
    let b_star = theory::dane_b_star(&consts, n as f64, dim);
    benchkit::section(&format!(
        "Table 2: MP-DANE regimes (n={n}, m={m}, b* = {b_star:.0})"
    ));
    println!(
        "{:<26} {:>8} {:>12} {:>12} {:>10} {:>12}",
        "regime", "b", "comm_rounds", "vec_ops", "memory", "objective"
    );

    let cases: Vec<(&str, usize)> = vec![
        ("b << b*", ((b_star * 0.25) as usize).max(64)),
        ("b = b*", (b_star as usize).max(64)),
        ("b* < b <= b_max", ((b_star * 4.0) as usize).min(n / m).max(256)),
    ];
    for (label, b) in cases {
        let plan = theory::mbprox_plan(&consts, n as f64, b);
        let dp = theory::dane_plan(&consts, &plan, b, n as f64, dim);
        let eta = 0.1 / (consts.beta_smooth + plan.gamma + dp.kappa);
        let solver = if dp.kappa > 0.0 && dp.r_outer > 1 {
            DaneSolver::aide(dp.k_inner, dp.r_outer, dp.kappa, eta)
        } else {
            DaneSolver::plain(dp.k_inner, eta)
        };
        let mut method = MinibatchProx::new("mp-dane", b, plan.t_outer, plan.gamma, solver);

        // context over planted least squares
        let root = SynthStream::new(SynthSpec::least_squares(dim), 23);
        let streams: Vec<Box<dyn SampleStream>> = (0..m)
            .map(|i| Box::new(root.fork_stream(i as u64)) as Box<dyn SampleStream>)
            .collect();
        let mut eval_stream = root.fork_stream(999);
        let eval_samples = eval_stream.draw_many(2048);
        let mut ctx =
            runner.context_over(Loss::Squared, dim, streams, &eval_samples, 0).unwrap();
        match method.run(&mut ctx) {
            Ok(r) => println!(
                "{:<26} {:>8} {:>12} {:>12} {:>10} {:>12}",
                format!("{label} [{}]", if dp.kappa > 0.0 { "aide" } else { "plain" }),
                b,
                r.report.comm_rounds,
                r.report.vec_ops,
                r.report.peak_vectors,
                r.final_objective.map(|o| format!("{o:.5}")).unwrap_or_default()
            ),
            Err(e) => println!("{label}: ERROR {e}"),
        }
    }
}
