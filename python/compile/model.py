"""L2: the JAX compute graphs that become the AOT artifacts.

Each artifact is a pure function over fixed-shape block operands that calls
the L1 Pallas kernels, so the kernel lowers into the same HLO module.  The
registry below is the single source of truth consumed by ``aot.py`` (which
lowers every entry to HLO text) and by the pytest suite (which checks each
entry against the ``ref.py`` oracles before lowering).

All functions return *tuples* (lowered with ``return_tuple=True``), matching
the rust loader's ``to_tupleN`` unwrapping.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax

from .kernels import (
    BLOCK,
    DIMS,
    DTYPE,
    LOSSES,
    LOSS_SQUARED,
    MULTI_KS,
    artifact_name,
    block_grad,
    block_grad_multi,
    multi_artifact_name,
    normal_matvec,
    normal_matvec_multi,
    saga_block,
    svrg_block,
)


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT artifact: a jittable fn plus its example (shape) arguments."""

    name: str
    fn: Callable
    arg_shapes: tuple[tuple[int, ...], ...]
    # metadata recorded in the manifest for the rust registry
    kind: str = ""  # grad | svrg | saga | nm | grad_multi | nm_multi
    loss: str = ""
    d: int = 0
    block: int = BLOCK
    outputs: tuple[str, ...] = field(default=())
    # stacked blocks per dispatch (1 = single-block artifact)
    k: int = 1

    def example_args(self):
        return tuple(jax.ShapeDtypeStruct(s, DTYPE) for s in self.arg_shapes)


def _grad_fn(loss: str):
    def fn(X, y, mask, w):
        g, l, c = block_grad(loss, X, y, mask, w)
        return (g, l, c)

    fn.__name__ = f"grad_{loss}"
    return fn


def _svrg_fn(loss: str):
    def fn(X, y, mask, x0, z, mu, wprev, gamma, eta):
        x_out, x_avg = svrg_block(loss, X, y, mask, x0, z, mu, wprev, gamma, eta)
        return (x_out, x_avg)

    fn.__name__ = f"svrg_{loss}"
    return fn


def _saga_fn(loss: str):
    def fn(X, y, mask, x0, z, mu, center, gamma, eta):
        x_out, x_avg = saga_block(loss, X, y, mask, x0, z, mu, center, gamma, eta)
        return (x_out, x_avg)

    fn.__name__ = f"saga_{loss}"
    return fn


def _nm_fn():
    def fn(X, mask, v):
        out, c = normal_matvec(X, mask, v)
        return (out, c)

    fn.__name__ = "nm_sq"
    return fn


def _grad_multi_fn(loss: str, k: int):
    def fn(X, y, mask, w):
        g, l, c = block_grad_multi(loss, k, X, y, mask, w)
        return (g, l, c)

    fn.__name__ = f"gradm{k}_{loss}"
    return fn


def _nm_multi_fn(k: int):
    def fn(X, mask, v):
        out, c = normal_matvec_multi(k, X, mask, v)
        return (out, c)

    fn.__name__ = f"nmm{k}_sq"
    return fn


def build_registry(
    block: int = BLOCK, dims=DIMS, multi_ks=MULTI_KS
) -> dict[str, ArtifactSpec]:
    """All artifacts, keyed by canonical name (see kernels.artifact_name)."""
    reg: dict[str, ArtifactSpec] = {}
    for d in dims:
        for loss in LOSSES:
            name = artifact_name("grad", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_grad_fn(loss),
                arg_shapes=((block, d), (block,), (block,), (d,)),
                kind="grad",
                loss=loss,
                d=d,
                block=block,
                outputs=("grad_sum", "loss_sum", "count"),
            )
            name = artifact_name("svrg", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_svrg_fn(loss),
                arg_shapes=(
                    (block, d), (block,), (block,),
                    (d,), (d,), (d,), (d,), (1,), (1,),
                ),
                kind="svrg",
                loss=loss,
                d=d,
                block=block,
                outputs=("x_out", "x_avg"),
            )
            name = artifact_name("saga", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_saga_fn(loss),
                arg_shapes=(
                    (block, d), (block,), (block,),
                    (d,), (d,), (d,), (d,), (1,), (1,),
                ),
                kind="saga",
                loss=loss,
                d=d,
                block=block,
                outputs=("x_out", "x_avg"),
            )
        name = artifact_name("nm", LOSS_SQUARED, d)
        reg[name] = ArtifactSpec(
            name=name,
            fn=_nm_fn(),
            arg_shapes=((block, d), (block,), (d,)),
            kind="nm",
            loss=LOSS_SQUARED,
            d=d,
            block=block,
            outputs=("xtxv_sum", "count"),
        )
        # fused multi-block dispatch: K stacked blocks per call, grad/count
        # reduced on device (see kernels/grad.py *_multi)
        for k in multi_ks:
            for loss in LOSSES:
                name = multi_artifact_name("grad", loss, d, k)
                reg[name] = ArtifactSpec(
                    name=name,
                    fn=_grad_multi_fn(loss, k),
                    arg_shapes=((k * block, d), (k * block,), (k * block,), (d,)),
                    kind="grad_multi",
                    loss=loss,
                    d=d,
                    block=block,
                    outputs=("grad_sum", "loss_sum", "count"),
                    k=k,
                )
            name = multi_artifact_name("nm", LOSS_SQUARED, d, k)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_nm_multi_fn(k),
                arg_shapes=((k * block, d), (k * block,), (d,)),
                kind="nm_multi",
                loss=LOSS_SQUARED,
                d=d,
                block=block,
                outputs=("xtxv_sum", "count"),
                k=k,
            )
    return reg


def lower_to_hlo_text(spec: ArtifactSpec) -> str:
    """Lower one artifact to HLO *text* (the interchange format).

    jax >= 0.5 serializes HloModuleProto with 64-bit instruction ids which
    xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6
    crate) rejects; the HLO text parser reassigns ids and round-trips
    cleanly.  Lowered with return_tuple=True; rust unwraps with to_tupleN.
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(spec.fn).lower(*spec.example_args())
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
