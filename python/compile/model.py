"""L2: the JAX compute graphs that become the AOT artifacts.

Each artifact is a pure function over fixed-shape block operands that calls
the L1 Pallas kernels, so the kernel lowers into the same HLO module.  The
registry below is the single source of truth consumed by ``aot.py`` (which
lowers every entry to HLO text) and by the pytest suite (which checks each
entry against the ``ref.py`` oracles before lowering).

All functions return *tuples* (lowered with ``return_tuple=True``), matching
the rust loader's ``to_tupleN`` unwrapping.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax

from .kernels import (
    BLOCK,
    CHAIN_KS,
    DIMS,
    DTYPE,
    LOSSES,
    LOSS_SQUARED,
    MULTI_KS,
    RED_MS,
    STATE_ROWS,
    artifact_name,
    block_grad,
    block_grad_multi,
    chain_artifact_name,
    grad_acc,
    multi_artifact_name,
    nm_acc,
    normal_matvec,
    normal_matvec_multi,
    red_artifact_name,
    reduce_weighted,
    saga_block,
    svrg_block,
    vec_artifact_name,
    vec_axpby,
    vec_dot,
    vec_scale,
    vr_avg,
    vr_chain,
    vr_reset,
)


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT artifact: a jittable fn plus its example (shape) arguments."""

    name: str
    fn: Callable
    arg_shapes: tuple[tuple[int, ...], ...]
    # metadata recorded in the manifest for the rust registry
    kind: str = ""  # grad | svrg | saga | nm | grad_multi | nm_multi
    #                 | gacc | nacc | svrgc | sagac
    #                 | vscale | vaxpby | vdot | vravg | vrreset | red
    loss: str = ""
    d: int = 0
    block: int = BLOCK
    outputs: tuple[str, ...] = field(default=())
    # stacked blocks per dispatch (1 = single-block artifact); for the
    # cross-machine ``red`` kind this is the machine count M instead
    k: int = 1
    # chained artifacts return ONE array (lowered return_tuple=False) so
    # the rust engine can feed the output buffer into the next dispatch
    chained: bool = False
    # trace/lower under scoped x64 (the f64-interior reduce kernel only);
    # everything else lowers under the x32 default, byte-identically
    x64: bool = False

    def example_args(self):
        return tuple(jax.ShapeDtypeStruct(s, DTYPE) for s in self.arg_shapes)


def _grad_fn(loss: str):
    def fn(X, y, mask, w):
        g, l, c = block_grad(loss, X, y, mask, w)
        return (g, l, c)

    fn.__name__ = f"grad_{loss}"
    return fn


def _svrg_fn(loss: str):
    def fn(X, y, mask, x0, z, mu, wprev, gamma, eta):
        x_out, x_avg = svrg_block(loss, X, y, mask, x0, z, mu, wprev, gamma, eta)
        return (x_out, x_avg)

    fn.__name__ = f"svrg_{loss}"
    return fn


def _saga_fn(loss: str):
    def fn(X, y, mask, x0, z, mu, center, gamma, eta):
        x_out, x_avg = saga_block(loss, X, y, mask, x0, z, mu, center, gamma, eta)
        return (x_out, x_avg)

    fn.__name__ = f"saga_{loss}"
    return fn


def _nm_fn():
    def fn(X, mask, v):
        out, c = normal_matvec(X, mask, v)
        return (out, c)

    fn.__name__ = "nm_sq"
    return fn


def _grad_multi_fn(loss: str, k: int):
    def fn(X, y, mask, w):
        g, l, c = block_grad_multi(loss, k, X, y, mask, w)
        return (g, l, c)

    fn.__name__ = f"gradm{k}_{loss}"
    return fn


def _nm_multi_fn(k: int):
    def fn(X, mask, v):
        out, c = normal_matvec_multi(k, X, mask, v)
        return (out, c)

    fn.__name__ = f"nmm{k}_sq"
    return fn


def _gacc_fn(loss: str, k: int):
    def fn(X, y, mask, w, acc):
        return grad_acc(loss, k, X, y, mask, w, acc)

    fn.__name__ = f"gacc{k}_{loss}"
    return fn


def _nacc_fn(k: int):
    def fn(X, mask, v, acc):
        return nm_acc(k, X, mask, v, acc)

    fn.__name__ = f"nacc{k}_sq"
    return fn


def _vr_chain_fn(solver: str, loss: str, k: int):
    def fn(X, y, mask, S, z, mu, center, gamma, eta):
        return vr_chain(solver, loss, k, X, y, mask, S, z, mu, center, gamma, eta)

    fn.__name__ = f"{solver}c{k}_{loss}"
    return fn


def _red_fn(m: int):
    def fn(*args):
        return reduce_weighted(m, args[:m], args[m])

    fn.__name__ = f"redm{m}"
    return fn


_VEC_FNS: dict[str, Callable] = {
    "vscale": lambda X, s: vec_scale(X, s),
    "vaxpby": lambda u, v, a, b: vec_axpby(u, v, a, b),
    "vdot": lambda u, v: vec_dot(u, v),
    "vravg": lambda S, invw: vr_avg(S, invw),
    "vrreset": lambda S: vr_reset(S),
}


def _vec_shapes(kind: str, d: int) -> tuple[tuple[int, ...], ...]:
    return {
        "vscale": ((d,), (1,)),
        "vaxpby": ((d,), (d,), (1,), (1,)),
        "vdot": ((d,), (d,)),
        "vravg": ((STATE_ROWS, d), (1,)),
        "vrreset": ((STATE_ROWS, d),),
    }[kind]


def _vec_out(kind: str) -> tuple[str, ...]:
    return ("state",) if kind == "vrreset" else ("out",)


def build_registry(
    block: int = BLOCK, dims=DIMS, multi_ks=MULTI_KS, chain_ks=CHAIN_KS, red_ms=RED_MS
) -> dict[str, ArtifactSpec]:
    """All artifacts, keyed by canonical name (see kernels.artifact_name)."""
    reg: dict[str, ArtifactSpec] = {}
    for d in dims:
        for loss in LOSSES:
            name = artifact_name("grad", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_grad_fn(loss),
                arg_shapes=((block, d), (block,), (block,), (d,)),
                kind="grad",
                loss=loss,
                d=d,
                block=block,
                outputs=("grad_sum", "loss_sum", "count"),
            )
            name = artifact_name("svrg", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_svrg_fn(loss),
                arg_shapes=(
                    (block, d), (block,), (block,),
                    (d,), (d,), (d,), (d,), (1,), (1,),
                ),
                kind="svrg",
                loss=loss,
                d=d,
                block=block,
                outputs=("x_out", "x_avg"),
            )
            name = artifact_name("saga", loss, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_saga_fn(loss),
                arg_shapes=(
                    (block, d), (block,), (block,),
                    (d,), (d,), (d,), (d,), (1,), (1,),
                ),
                kind="saga",
                loss=loss,
                d=d,
                block=block,
                outputs=("x_out", "x_avg"),
            )
        name = artifact_name("nm", LOSS_SQUARED, d)
        reg[name] = ArtifactSpec(
            name=name,
            fn=_nm_fn(),
            arg_shapes=((block, d), (block,), (d,)),
            kind="nm",
            loss=LOSS_SQUARED,
            d=d,
            block=block,
            outputs=("xtxv_sum", "count"),
        )
        # fused multi-block dispatch: K stacked blocks per call, grad/count
        # reduced on device (see kernels/grad.py *_multi)
        for k in multi_ks:
            for loss in LOSSES:
                name = multi_artifact_name("grad", loss, d, k)
                reg[name] = ArtifactSpec(
                    name=name,
                    fn=_grad_multi_fn(loss, k),
                    arg_shapes=((k * block, d), (k * block,), (k * block,), (d,)),
                    kind="grad_multi",
                    loss=loss,
                    d=d,
                    block=block,
                    outputs=("grad_sum", "loss_sum", "count"),
                    k=k,
                )
            name = multi_artifact_name("nm", LOSS_SQUARED, d, k)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_nm_multi_fn(k),
                arg_shapes=((k * block, d), (k * block,), (d,)),
                kind="nm_multi",
                loss=LOSS_SQUARED,
                d=d,
                block=block,
                outputs=("xtxv_sum", "count"),
                k=k,
            )
        # the device-resident vector plane: single-output chained artifacts
        # (return_tuple=False) whose outputs feed the next dispatch without
        # a download — see kernels/chain.py
        for k in chain_ks:
            for loss in LOSSES:
                name = chain_artifact_name("gacc", loss, d, k)
                reg[name] = ArtifactSpec(
                    name=name,
                    fn=_gacc_fn(loss, k),
                    arg_shapes=((k * block, d), (k * block,), (k * block,), (d,), (d,)),
                    kind="gacc",
                    loss=loss,
                    d=d,
                    block=block,
                    outputs=("grad_acc",),
                    k=k,
                    chained=True,
                )
                for solver in ("svrg", "saga"):
                    name = chain_artifact_name(f"{solver}c", loss, d, k)
                    reg[name] = ArtifactSpec(
                        name=name,
                        fn=_vr_chain_fn(solver, loss, k),
                        arg_shapes=(
                            (k * block, d), (k * block,), (k * block,),
                            (STATE_ROWS, d), (d,), (d,), (d,), (1,), (1,),
                        ),
                        kind=f"{solver}c",
                        loss=loss,
                        d=d,
                        block=block,
                        outputs=("state",),
                        k=k,
                        chained=True,
                    )
            name = chain_artifact_name("nacc", LOSS_SQUARED, d, k)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_nacc_fn(k),
                arg_shapes=((k * block, d), (k * block,), (d,), (d,)),
                kind="nacc",
                loss=LOSS_SQUARED,
                d=d,
                block=block,
                outputs=("xtxv_acc",),
                k=k,
                chained=True,
            )
        for kind, fn in _VEC_FNS.items():
            name = vec_artifact_name(kind, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=fn,
                arg_shapes=_vec_shapes(kind, d),
                kind=kind,
                d=d,
                block=block,
                outputs=_vec_out(kind),
                chained=True,
            )
        # cross-machine reduce: the DeviceCollective kernel (k records M)
        for m in red_ms:
            name = red_artifact_name(m, d)
            reg[name] = ArtifactSpec(
                name=name,
                fn=_red_fn(m),
                arg_shapes=tuple([(d,)] * m + [(m,)]),
                kind="red",
                d=d,
                block=block,
                outputs=("mean",),
                k=m,
                chained=True,
                x64=True,
            )
    return reg


def lower_to_hlo_text(spec: ArtifactSpec) -> str:
    """Lower one artifact to HLO *text* (the interchange format).

    jax >= 0.5 serializes HloModuleProto with 64-bit instruction ids which
    xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6
    crate) rejects; the HLO text parser reassigns ids and round-trips
    cleanly.  Tupled artifacts lower with return_tuple=True (rust unwraps
    with decompose_tuple); chained artifacts lower with return_tuple=False
    so the single output buffer chains into the next dispatch as-is.
    """
    import contextlib

    from jax._src.lib import xla_client as xc
    from jax.experimental import enable_x64

    scope = enable_x64() if spec.x64 else contextlib.nullcontext()
    with scope:
        lowered = jax.jit(spec.fn).lower(*spec.example_args())
        mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=not spec.chained
    )
    return comp.as_hlo_text()
