"""AOT driver: lower every registry artifact to HLO text + manifest.json.

Run once at build time (``make artifacts``); python never appears on the
rust request path.  Emits HLO *text* (NOT ``.serialize()``): the image's
xla_extension 0.5.1 rejects jax >= 0.5's 64-bit-id protos, while the text
parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

from .kernels import BLOCK, DIMS
from .model import build_registry, lower_to_hlo_text


def emit_all(out_dir: str, block: int = BLOCK, dims=DIMS) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    registry = build_registry(block=block, dims=dims)
    manifest = {"block": block, "dims": list(dims), "artifacts": []}
    for name in sorted(registry):
        spec = registry[name]
        text = lower_to_hlo_text(spec)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": spec.name,
                "file": f"{name}.hlo.txt",
                "kind": spec.kind,
                "loss": spec.loss,
                "d": spec.d,
                "block": spec.block,
                "arg_shapes": [list(s) for s in spec.arg_shapes],
                "outputs": list(spec.outputs),
                "k": spec.k,
                "chained": spec.chained,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  lowered {name:>14s} -> {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored; use --out-dir")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        # legacy Makefile passed a single file path; emit to its directory
        out_dir = os.path.dirname(args.out) or "."
    emit_all(out_dir)


if __name__ == "__main__":
    main()
