"""Shared constants and helpers for the L1 Pallas kernels.

All artifacts operate on fixed-shape *blocks* of data: `BLOCK` rows of a
feature matrix padded to one of the supported feature dimensions `DIMS`.
A 0/1 `mask` column marks the valid rows so that tail padding is a no-op;
gradients and losses are returned as **sums plus a valid-row count**, which
lets the rust coordinator combine arbitrary block partitions exactly.

A 256x128 f32 block is 128 KiB, so a whole block together with its labels,
mask and every vector operand is VMEM-resident on a real TPU; each kernel
is therefore a single grid step with full fusion (see DESIGN.md
SS-Hardware-Adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp

# Rows per data block. Chosen so that a full (BLOCK, 128) f32 tile plus all
# vector operands fits comfortably in a single VMEM-resident grid step.
BLOCK: int = 256

# Supported (padded) feature dimensions. Table 3 datasets map as:
# codrna(8) -> 64, covtype(54) -> 64, year(90) -> 128, kddcup99(127) -> 128.
DIMS: tuple[int, ...] = (64, 128)

# Loss tags used in artifact names.
LOSS_SQUARED = "sq"
LOSS_LOGISTIC = "log"
LOSSES: tuple[str, ...] = (LOSS_SQUARED, LOSS_LOGISTIC)

DTYPE = jnp.float32


def artifact_name(kind: str, loss: str, d: int) -> str:
    """Canonical artifact name, e.g. ``grad_sq_d64``.

    ``kind`` is one of ``grad``, ``svrg``, ``saga``, ``nm``; ``nm`` (the regularized
    normal-equation matvec) exists only for the squared loss.
    """
    if kind not in ("grad", "svrg", "saga", "nm"):
        raise ValueError(f"unknown artifact kind: {kind}")
    if loss not in LOSSES:
        raise ValueError(f"unknown loss: {loss}")
    if kind == "nm" and loss != LOSS_SQUARED:
        raise ValueError("normal-equation matvec only exists for squared loss")
    return f"{kind}_{loss}_d{d}"
