"""Shared constants and helpers for the L1 Pallas kernels.

All artifacts operate on fixed-shape *blocks* of data: `BLOCK` rows of a
feature matrix padded to one of the supported feature dimensions `DIMS`.
A 0/1 `mask` column marks the valid rows so that tail padding is a no-op;
gradients and losses are returned as **sums plus a valid-row count**, which
lets the rust coordinator combine arbitrary block partitions exactly.

A 256x128 f32 block is 128 KiB, so a whole block together with its labels,
mask and every vector operand is VMEM-resident on a real TPU; each kernel
is therefore a single grid step with full fusion (see DESIGN.md
SS-Hardware-Adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp

# Rows per data block. Chosen so that a full (BLOCK, 128) f32 tile plus all
# vector operands fits comfortably in a single VMEM-resident grid step.
BLOCK: int = 256

# Supported (padded) feature dimensions. Table 3 datasets map as:
# codrna(8) -> 64, covtype(54) -> 64, year(90) -> 128, kddcup99(127) -> 128.
DIMS: tuple[int, ...] = (64, 128)

# Loss tags used in artifact names.
LOSS_SQUARED = "sq"
LOSS_LOGISTIC = "log"
LOSSES: tuple[str, ...] = (LOSS_SQUARED, LOSS_LOGISTIC)

# Stacked-block widths for the fused multi-block dispatch artifacts
# (``gradm{K}`` / ``nmm{K}``): one device call consumes K blocks and
# reduces their grad-sums on device. The rust packer greedily groups a
# machine batch into the largest supported K with a per-block fallback
# for the ragged tail.
MULTI_KS: tuple[int, ...] = (4, 8)

# Widths of the *chained* artifacts (``gacc{K}``/``nacc{K}``/``svrgc{K}``/
# ``sagac{K}``): unlike the fused downloads above these include K=1 so the
# ragged single-block tail of a fused group list can stay on device too.
CHAIN_KS: tuple[int, ...] = (1,) + MULTI_KS

# Machine counts served by the cross-machine reduce artifacts (``redm{M}``).
# The rust DeviceCollective falls back to the host collective (with
# identical round/vector accounting) for unsupported cluster sizes.
RED_MS: tuple[int, ...] = (2, 4, 8)

# Rows of the chained VR sweep state: S[0] is the loop-carried iterate x,
# S[1] the weighted running-average accumulator (sum of per-block xsums).
STATE_ROWS: int = 2

DTYPE = jnp.float32


def artifact_name(kind: str, loss: str, d: int) -> str:
    """Canonical artifact name, e.g. ``grad_sq_d64``.

    ``kind`` is one of ``grad``, ``svrg``, ``saga``, ``nm``; ``nm`` (the regularized
    normal-equation matvec) exists only for the squared loss.
    """
    if kind not in ("grad", "svrg", "saga", "nm"):
        raise ValueError(f"unknown artifact kind: {kind}")
    if loss not in LOSSES:
        raise ValueError(f"unknown loss: {loss}")
    if kind == "nm" and loss != LOSS_SQUARED:
        raise ValueError("normal-equation matvec only exists for squared loss")
    return f"{kind}_{loss}_d{d}"


def multi_artifact_name(kind: str, loss: str, d: int, k: int) -> str:
    """Canonical fused multi-block artifact name, e.g. ``gradm4_sq_d64``.

    ``kind`` is ``grad`` or ``nm`` (only the download-per-call hot paths
    have fused variants; the VR sweep kernels stay per-block).
    """
    if kind not in ("grad", "nm"):
        raise ValueError(f"no multi-block variant for kind: {kind}")
    if k < 2:
        raise ValueError(f"multi-block width must be >= 2, got {k}")
    # reuse the single-block validation for loss/kind compatibility
    artifact_name(kind, loss, d)
    return f"{kind}m{k}_{loss}_d{d}"


def chain_artifact_name(kind: str, loss: str, d: int, k: int) -> str:
    """Canonical *chained* artifact name, e.g. ``gacc4_sq_d64``.

    Chained artifacts return a single device-resident array (no tuple, no
    download): ``gacc`` accumulates block gradient sums into a carried
    vector, ``nacc`` the normal-equation matvec sums, and ``svrgc``/
    ``sagac`` carry the VR sweep state ``[x; avg_accum]`` across fused
    groups. The width ``k`` (number of stacked blocks) is always embedded,
    including k=1 — the chained family has no single/multi dichotomy.
    """
    if kind not in ("gacc", "nacc", "svrgc", "sagac"):
        raise ValueError(f"unknown chained artifact kind: {kind}")
    if loss not in LOSSES:
        raise ValueError(f"unknown loss: {loss}")
    if kind == "nacc" and loss != LOSS_SQUARED:
        raise ValueError("normal-equation matvec only exists for squared loss")
    if k < 1:
        raise ValueError(f"chained width must be >= 1, got {k}")
    return f"{kind}{k}_{loss}_d{d}"


def vec_artifact_name(kind: str, d: int) -> str:
    """Canonical device vector-plane artifact name, e.g. ``vaxpby_d64``.

    The vector plane is the loss-independent glue of the chained pipeline:
    ``vscale`` (s*x), ``vaxpby`` (a*u + b*v), ``vdot`` (scalar dot),
    ``vravg`` (extract the sweep average from a VR state), ``vrreset``
    (zero a VR state's accumulator, keep its iterate).
    """
    if kind not in ("vscale", "vaxpby", "vdot", "vravg", "vrreset"):
        raise ValueError(f"unknown vector-plane artifact kind: {kind}")
    return f"{kind}_d{d}"


def red_artifact_name(m: int, d: int) -> str:
    """Canonical cross-machine reduce artifact name, e.g. ``redm4_d64``.

    ``redm{M}`` consumes M machine vectors plus an M-weight vector and
    produces their weighted mean, accumulating in f64 in machine order so
    the downloaded result is bit-identical to the host collective
    (``Network::all_reduce_weighted``).
    """
    if m < 2:
        raise ValueError(f"cross-machine reduce needs m >= 2, got {m}")
    return f"redm{m}_d{d}"
