"""Shared constants and helpers for the L1 Pallas kernels.

All artifacts operate on fixed-shape *blocks* of data: `BLOCK` rows of a
feature matrix padded to one of the supported feature dimensions `DIMS`.
A 0/1 `mask` column marks the valid rows so that tail padding is a no-op;
gradients and losses are returned as **sums plus a valid-row count**, which
lets the rust coordinator combine arbitrary block partitions exactly.

A 256x128 f32 block is 128 KiB, so a whole block together with its labels,
mask and every vector operand is VMEM-resident on a real TPU; each kernel
is therefore a single grid step with full fusion (see DESIGN.md
SS-Hardware-Adaptation).
"""

from __future__ import annotations

import jax.numpy as jnp

# Rows per data block. Chosen so that a full (BLOCK, 128) f32 tile plus all
# vector operands fits comfortably in a single VMEM-resident grid step.
BLOCK: int = 256

# Supported (padded) feature dimensions. Table 3 datasets map as:
# codrna(8) -> 64, covtype(54) -> 64, year(90) -> 128, kddcup99(127) -> 128.
DIMS: tuple[int, ...] = (64, 128)

# Loss tags used in artifact names.
LOSS_SQUARED = "sq"
LOSS_LOGISTIC = "log"
LOSSES: tuple[str, ...] = (LOSS_SQUARED, LOSS_LOGISTIC)

# Stacked-block widths for the fused multi-block dispatch artifacts
# (``gradm{K}`` / ``nmm{K}``): one device call consumes K blocks and
# reduces their grad-sums on device. The rust packer greedily groups a
# machine batch into the largest supported K with a per-block fallback
# for the ragged tail.
MULTI_KS: tuple[int, ...] = (4, 8)

DTYPE = jnp.float32


def artifact_name(kind: str, loss: str, d: int) -> str:
    """Canonical artifact name, e.g. ``grad_sq_d64``.

    ``kind`` is one of ``grad``, ``svrg``, ``saga``, ``nm``; ``nm`` (the regularized
    normal-equation matvec) exists only for the squared loss.
    """
    if kind not in ("grad", "svrg", "saga", "nm"):
        raise ValueError(f"unknown artifact kind: {kind}")
    if loss not in LOSSES:
        raise ValueError(f"unknown loss: {loss}")
    if kind == "nm" and loss != LOSS_SQUARED:
        raise ValueError("normal-equation matvec only exists for squared loss")
    return f"{kind}_{loss}_d{d}"


def multi_artifact_name(kind: str, loss: str, d: int, k: int) -> str:
    """Canonical fused multi-block artifact name, e.g. ``gradm4_sq_d64``.

    ``kind`` is ``grad`` or ``nm`` (only the download-per-call hot paths
    have fused variants; the VR sweep kernels stay per-block).
    """
    if kind not in ("grad", "nm"):
        raise ValueError(f"no multi-block variant for kind: {kind}")
    if k < 2:
        raise ValueError(f"multi-block width must be >= 2, got {k}")
    # reuse the single-block validation for loss/kind compatibility
    artifact_name(kind, loss, d)
    return f"{kind}m{k}_{loss}_d{d}"
