"""L1 Pallas kernels: fused block gradient + loss for squared / logistic loss.

The block gradient is the compute hot-spot of every method in the paper
(minibatch SGD, the DSVRG full-gradient rounds, DANE local objectives, CG
matvecs all reduce to it).  Each kernel fuses, in one VMEM-resident pass:

    squared:   r = (X @ w - y) * mask ; grad = X^T r ; loss = 0.5 * sum r^2
    logistic:  t = -y * (X @ w) ;  s = sigmoid(t) * mask
               grad = X^T (-y * s) ; loss = sum(mask * softplus(t))

The two contractions (``X @ w`` and ``X^T r``) are MXU-eligible matmuls on
a real TPU; everything between them is a VPU epilogue.  Kernels are lowered
with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).

The ``*_multi`` variants consume K stacked blocks (``K*B`` rows) in ONE
dispatch: a 1-D grid walks the K sub-blocks while the outputs stay pinned
to the same block, so the cross-block reduction of grad/loss/count happens
*on device* and the host downloads a single ``(grad_sum, loss_sum, count)``
tuple per group instead of one per block.  Each grid step is still one
VMEM-resident ``(B, d)`` tile, so the multi kernels keep the same VMEM
footprint as the single-block kernels on a real TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, LOSS_LOGISTIC, LOSS_SQUARED


def _grad_sq_kernel(x_ref, y_ref, m_ref, w_ref, g_ref, loss_ref, cnt_ref):
    X = x_ref[...]  # [B, d]
    y = y_ref[...]  # [B]
    mask = m_ref[...]  # [B], 0/1
    w = w_ref[...]  # [d]
    # residual, masked so padded rows contribute nothing
    r = (jnp.dot(X, w) - y) * mask  # [B]   (MXU matvec + VPU epilogue)
    g_ref[...] = jnp.dot(r, X)  # X^T r   (MXU)
    loss_ref[...] = 0.5 * jnp.sum(r * r, keepdims=True)  # mask is 0/1 => mask^2 == mask
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def _grad_log_kernel(x_ref, y_ref, m_ref, w_ref, g_ref, loss_ref, cnt_ref):
    X = x_ref[...]
    y = y_ref[...]  # labels in {-1, +1}
    mask = m_ref[...]
    w = w_ref[...]
    t = -y * jnp.dot(X, w)  # [B]
    s = jax.nn.sigmoid(t) * mask
    g_ref[...] = jnp.dot(-y * s, X)  # X^T(-y * sigmoid(-y Xw))
    # numerically stable softplus: log(1 + e^t)
    loss_ref[...] = jnp.sum(mask * jnp.logaddexp(0.0, t), keepdims=True)
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def block_grad(loss: str, X, y, mask, w):
    """Fused block gradient: returns ``(grad_sum[d], loss_sum[1], count[1])``.

    ``grad_sum`` is the *sum* over valid rows of per-sample gradients (not
    the mean) — callers divide by the total valid count across blocks.
    """
    b, d = X.shape
    kernel = _grad_sq_kernel if loss == LOSS_SQUARED else _grad_log_kernel
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, y, mask, w)


def _make_grad_multi_kernel(loss: str):
    """One grid step = one stacked sub-block; outputs accumulate in place."""

    def kernel(x_ref, y_ref, m_ref, w_ref, g_ref, loss_ref, cnt_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            g_ref[...] = jnp.zeros_like(g_ref)
            loss_ref[...] = jnp.zeros_like(loss_ref)
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        X = x_ref[...]  # [B, d] — this grid step's sub-block
        y = y_ref[...]
        mask = m_ref[...]
        w = w_ref[...]
        if loss == LOSS_SQUARED:
            r = (jnp.dot(X, w) - y) * mask
            g_ref[...] += jnp.dot(r, X)
            loss_ref[...] += 0.5 * jnp.sum(r * r, keepdims=True)
        else:
            t = -y * jnp.dot(X, w)
            s = jax.nn.sigmoid(t) * mask
            g_ref[...] += jnp.dot(-y * s, X)
            loss_ref[...] += jnp.sum(mask * jnp.logaddexp(0.0, t), keepdims=True)
        cnt_ref[...] += jnp.sum(mask, keepdims=True)

    return kernel


def block_grad_multi(loss: str, k: int, X, y, mask, w):
    """Fused K-block gradient with on-device reduction.

    ``X`` is ``[K*B, d]`` (K stacked blocks), ``y``/``mask`` are ``[K*B]``.
    Returns the same ``(grad_sum[d], loss_sum[1], count[1])`` contract as
    :func:`block_grad` summed over all K blocks — block composition stays
    exact because padded rows are masked no-ops.
    """
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    rows, d = X.shape
    if k <= 0 or rows % k != 0:
        raise ValueError(f"rows {rows} not divisible into k={k} blocks")
    b = rows // k
    return pl.pallas_call(
        _make_grad_multi_kernel(loss),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, y, mask, w)


def _nm_sq_kernel(x_ref, m_ref, v_ref, out_ref, cnt_ref):
    X = x_ref[...]
    mask = m_ref[...]
    v = v_ref[...]
    u = jnp.dot(X, v) * mask  # [B]  (MXU + VPU mask)
    out_ref[...] = jnp.dot(u, X)  # X^T diag(mask) X v  (MXU)
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def normal_matvec(X, mask, v):
    """Fused ``X^T diag(mask) X v`` (sum form) + valid count.

    This is the Hessian-vector product of the empirical squared loss (times
    the count); the rust CG solver assembles ``(1/n) X^T X v + gamma v``
    from block sums.  Also the core of the DiSCO-style distributed Newton
    baseline.
    """
    b, d = X.shape
    return pl.pallas_call(
        _nm_sq_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, mask, v)


def _nm_multi_kernel(x_ref, m_ref, v_ref, out_ref, cnt_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    X = x_ref[...]
    mask = m_ref[...]
    v = v_ref[...]
    u = jnp.dot(X, v) * mask
    out_ref[...] += jnp.dot(u, X)
    cnt_ref[...] += jnp.sum(mask, keepdims=True)


def normal_matvec_multi(k: int, X, mask, v):
    """Fused K-block ``X^T diag(mask) X v`` with on-device reduction.

    The multi-block companion of :func:`normal_matvec`: one dispatch per K
    stacked blocks, one downloaded ``(xtxv_sum, count)`` pair per group —
    the exact-CG / DiSCO Hessian-vector hot path.
    """
    rows, d = X.shape
    if k <= 0 or rows % k != 0:
        raise ValueError(f"rows {rows} not divisible into k={k} blocks")
    b = rows // k
    return pl.pallas_call(
        _nm_multi_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, mask, v)
