"""L1 Pallas kernels: fused block gradient + loss for squared / logistic loss.

The block gradient is the compute hot-spot of every method in the paper
(minibatch SGD, the DSVRG full-gradient rounds, DANE local objectives, CG
matvecs all reduce to it).  Each kernel fuses, in one VMEM-resident pass:

    squared:   r = (X @ w - y) * mask ; grad = X^T r ; loss = 0.5 * sum r^2
    logistic:  t = -y * (X @ w) ;  s = sigmoid(t) * mask
               grad = X^T (-y * s) ; loss = sum(mask * softplus(t))

The two contractions (``X @ w`` and ``X^T r``) are MXU-eligible matmuls on
a real TPU; everything between them is a VPU epilogue.  Kernels are lowered
with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, LOSS_LOGISTIC, LOSS_SQUARED


def _grad_sq_kernel(x_ref, y_ref, m_ref, w_ref, g_ref, loss_ref, cnt_ref):
    X = x_ref[...]  # [B, d]
    y = y_ref[...]  # [B]
    mask = m_ref[...]  # [B], 0/1
    w = w_ref[...]  # [d]
    # residual, masked so padded rows contribute nothing
    r = (jnp.dot(X, w) - y) * mask  # [B]   (MXU matvec + VPU epilogue)
    g_ref[...] = jnp.dot(r, X)  # X^T r   (MXU)
    loss_ref[...] = 0.5 * jnp.sum(r * r, keepdims=True)  # mask is 0/1 => mask^2 == mask
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def _grad_log_kernel(x_ref, y_ref, m_ref, w_ref, g_ref, loss_ref, cnt_ref):
    X = x_ref[...]
    y = y_ref[...]  # labels in {-1, +1}
    mask = m_ref[...]
    w = w_ref[...]
    t = -y * jnp.dot(X, w)  # [B]
    s = jax.nn.sigmoid(t) * mask
    g_ref[...] = jnp.dot(-y * s, X)  # X^T(-y * sigmoid(-y Xw))
    # numerically stable softplus: log(1 + e^t)
    loss_ref[...] = jnp.sum(mask * jnp.logaddexp(0.0, t), keepdims=True)
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def block_grad(loss: str, X, y, mask, w):
    """Fused block gradient: returns ``(grad_sum[d], loss_sum[1], count[1])``.

    ``grad_sum`` is the *sum* over valid rows of per-sample gradients (not
    the mean) — callers divide by the total valid count across blocks.
    """
    b, d = X.shape
    kernel = _grad_sq_kernel if loss == LOSS_SQUARED else _grad_log_kernel
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, y, mask, w)


def _nm_sq_kernel(x_ref, m_ref, v_ref, out_ref, cnt_ref):
    X = x_ref[...]
    mask = m_ref[...]
    v = v_ref[...]
    u = jnp.dot(X, v) * mask  # [B]  (MXU + VPU mask)
    out_ref[...] = jnp.dot(u, X)  # X^T diag(mask) X v  (MXU)
    cnt_ref[...] = jnp.sum(mask, keepdims=True)


def normal_matvec(X, mask, v):
    """Fused ``X^T diag(mask) X v`` (sum form) + valid count.

    This is the Hessian-vector product of the empirical squared loss (times
    the count); the rust CG solver assembles ``(1/n) X^T X v + gamma v``
    from block sums.  Also the core of the DiSCO-style distributed Newton
    baseline.
    """
    b, d = X.shape
    return pl.pallas_call(
        _nm_sq_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ),
        interpret=True,
    )(X, mask, v)
