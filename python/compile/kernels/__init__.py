"""L1 Pallas kernels for the minibatch-prox / MP-DSVRG / MP-DANE stack."""

from .common import (
    BLOCK,
    DIMS,
    DTYPE,
    LOSSES,
    LOSS_LOGISTIC,
    LOSS_SQUARED,
    MULTI_KS,
    artifact_name,
    multi_artifact_name,
)
from .grad import block_grad, block_grad_multi, normal_matvec, normal_matvec_multi
from .saga import saga_block
from .svrg import svrg_block

__all__ = [
    "BLOCK",
    "DIMS",
    "DTYPE",
    "LOSSES",
    "LOSS_LOGISTIC",
    "LOSS_SQUARED",
    "MULTI_KS",
    "artifact_name",
    "multi_artifact_name",
    "block_grad",
    "block_grad_multi",
    "saga_block",
    "normal_matvec",
    "normal_matvec_multi",
    "svrg_block",
]
