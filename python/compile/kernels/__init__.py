"""L1 Pallas kernels for the minibatch-prox / MP-DSVRG / MP-DANE stack."""

from .common import BLOCK, DIMS, DTYPE, LOSSES, LOSS_LOGISTIC, LOSS_SQUARED, artifact_name
from .grad import block_grad, normal_matvec
from .saga import saga_block
from .svrg import svrg_block

__all__ = [
    "BLOCK",
    "DIMS",
    "DTYPE",
    "LOSSES",
    "LOSS_LOGISTIC",
    "LOSS_SQUARED",
    "artifact_name",
    "block_grad",
    "saga_block",
    "normal_matvec",
    "svrg_block",
]
