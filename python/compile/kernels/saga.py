"""L1 Pallas kernel: sequential SAGA block pass (GLM-structured).

Appendix E runs MP-DANE with **SAGA** (Defazio et al. 2014) as the local
solver ("we use SAGA to solve each local DANE subproblem (33) and fix the
number of SAGA steps to b"). For GLM losses the per-sample gradient
factorizes as ``s_i(w) * x_i`` with a *scalar* link residual

    squared:   s_i(w) = x_i . w - y_i
    logistic:  s_i(w) = -y_i * sigmoid(-y_i * x_i . w)

so the SAGA gradient table is one scalar per sample (B scalars ~ B/d
"vectors" — negligible next to the b-sample minibatch itself, which is why
MP-DANE's memory row in Table 2 stays ~b).

One call = one without-replacement sweep:
  - alpha_i initialized to s_i(z) (the snapshot link residuals), so the
    first correction matches SVRG, then the table updates as rows are
    visited (true SAGA within the pass);
  - gbar (the running mean of stored gradients) starts at ``mu`` — the
    DANE global-gradient correction rides in exactly as in the SVRG kernel;
  - per valid row i:
        g     = (s_i(x) - alpha_i) x_i + gbar + gamma (x - center)
        x    <- x - eta g
        gbar <- gbar + (s_i(x) - alpha_i) x_i / n_valid
        alpha_i <- s_i(x)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, LOSS_LOGISTIC, LOSS_SQUARED


def _link_residual(loss: str, z, y):
    """Vectorized scalar link residual s(w) for all rows, given z = X w."""
    if loss == LOSS_SQUARED:
        return z - y
    return -y * jax.nn.sigmoid(-y * z)


def _make_saga_kernel(loss: str):
    def kernel(
        x_ref, y_ref, m_ref, x0_ref, z_ref, mu_ref, c_ref, gamma_ref, eta_ref,
        xout_ref, xavg_ref,
    ):
        X = x_ref[...]  # [B, d]
        y = y_ref[...]
        mask = m_ref[...]
        z = z_ref[...]
        mu = mu_ref[...]
        center = c_ref[...]
        gamma = gamma_ref[0]
        eta = eta_ref[0]
        x0 = x0_ref[...]
        n_valid = jnp.maximum(jnp.sum(mask), 1.0)

        # alpha_i = s_i(z) for every row (MXU matvec + VPU link epilogue)
        alpha0 = _link_residual(loss, jnp.dot(X, z), y)

        def body(r, carry):
            x, gbar, alpha, xsum, cnt = carry
            xi = X[r]
            yi = y[r]
            mi = mask[r]
            s_new = _link_residual(loss, jnp.dot(xi, x), yi)
            diff = s_new - alpha[r]
            g = diff * xi + gbar + gamma * (x - center)
            x_new = x - eta * g
            x = jnp.where(mi > 0, x_new, x)
            gbar = jnp.where(mi > 0, gbar + (diff / n_valid) * xi, gbar)
            alpha = alpha.at[r].set(jnp.where(mi > 0, s_new, alpha[r]))
            xsum = xsum + jnp.where(mi > 0, x, jnp.zeros_like(x))
            cnt = cnt + mi
            return (x, gbar, alpha, xsum, cnt)

        x, _gbar, _alpha, xsum, cnt = jax.lax.fori_loop(
            0, X.shape[0], body, (x0, mu, alpha0, x0, jnp.ones((), DTYPE))
        )
        xout_ref[...] = x
        xavg_ref[...] = xsum / cnt

    return kernel


def saga_block(loss: str, X, y, mask, x0, z, mu, center, gamma, eta):
    """One without-replacement SAGA sweep; returns ``(x_out, x_avg)``."""
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    b, d = X.shape
    return pl.pallas_call(
        _make_saga_kernel(loss),
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((d,), DTYPE),
        ),
        interpret=True,
    )(X, y, mask, x0, z, mu, center, gamma, eta)
