"""L1 Pallas kernels for the device-resident vector plane.

Every kernel here returns a **single array** (lowered with
``return_tuple=False``) so the rust engine can feed one dispatch's output
buffer straight into the next dispatch without a ``to_literal_sync``
round-trip.  Together they close the chained half of the backend contract
(upload / dispatch / **chain** / **reduce**):

- ``grad_acc`` / ``nm_acc``: the hot-path reductions with a carried
  accumulator input, so a machine's whole batch folds into one device
  vector with zero downloads (``out = acc + sum_over_blocks(...)``).
- ``vr_chain``: the SVRG/SAGA sweep with a ``[2, d]`` state ``S`` —
  ``S[0]`` is the loop-carried iterate, ``S[1]`` the weighted-average
  accumulator (a sum of per-block ``xsum`` vectors, gated so all-padding
  blocks contribute nothing, mirroring the host combiner exactly).
- ``vec_scale`` / ``vec_axpby`` / ``vec_dot`` / ``vr_avg`` / ``vr_reset``:
  the loss-independent vector glue (CG recurrences, mean extraction).
- ``reduce_weighted``: the cross-machine collective.  Accumulates in f64
  in machine order — the same IEEE operation sequence as the rust host
  collective — so the downloaded result is **bit-identical** to
  ``Network::all_reduce_weighted``/``all_reduce_avg`` on the same inputs.

The multi-block kernels reuse the sequential-grid accumulation idiom of
``grad.py``: a 1-D grid walks the K stacked sub-blocks while the output
stays pinned to block 0, so the cross-block reduction happens on device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, LOSS_LOGISTIC, LOSS_SQUARED, STATE_ROWS
from .saga import _link_residual
from .svrg import _row_grad_log, _row_grad_sq


def _check_width(rows: int, k: int) -> int:
    if k <= 0 or rows % k != 0:
        raise ValueError(f"rows {rows} not divisible into k={k} blocks")
    return rows // k


def _make_grad_acc_kernel(loss: str):
    """One grid step = one sub-block; out starts at the carried ``acc``."""

    def kernel(x_ref, y_ref, m_ref, w_ref, a_ref, out_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            out_ref[...] = a_ref[...]

        X = x_ref[...]  # [B, d]
        y = y_ref[...]
        mask = m_ref[...]
        w = w_ref[...]
        if loss == LOSS_SQUARED:
            r = (jnp.dot(X, w) - y) * mask
            out_ref[...] += jnp.dot(r, X)
        else:
            t = -y * jnp.dot(X, w)
            s = jax.nn.sigmoid(t) * mask
            out_ref[...] += jnp.dot(-y * s, X)

    return kernel


def grad_acc(loss: str, k: int, X, y, mask, w, acc):
    """Chained K-block gradient accumulation: ``acc + grad_sum(X, y, mask, w)``.

    The gradient itself matches :func:`..grad.block_grad`'s ``grad_sum``
    output summed over the K stacked blocks; seeding with the previous
    group's output chains a whole machine batch into one device vector.
    Loss/count are NOT produced — the steady-state chained path tracks the
    valid count host-side (it is known at pack time) and only evaluation
    checkpoints need losses.
    """
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    rows, d = X.shape
    b = _check_width(rows, k)
    return pl.pallas_call(
        _make_grad_acc_kernel(loss),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(X, y, mask, w, acc)


def _nm_acc_kernel(x_ref, m_ref, v_ref, a_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = a_ref[...]

    X = x_ref[...]
    mask = m_ref[...]
    v = v_ref[...]
    u = jnp.dot(X, v) * mask
    out_ref[...] += jnp.dot(u, X)


def nm_acc(k: int, X, mask, v, acc):
    """Chained K-block ``acc + X^T diag(mask) X v`` (squared loss only)."""
    rows, d = X.shape
    b = _check_width(rows, k)
    return pl.pallas_call(
        _nm_acc_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(X, mask, v, acc)


def _make_vr_chain_kernel(solver: str, loss: str):
    """Chained VR sweep: grid step i sweeps stacked sub-block i.

    The ``[2, d]`` output state is pinned across grid steps: ``out[0]``
    carries the iterate from sub-block to sub-block (bitwise identical to
    dispatching the per-block ``svrg``/``saga`` kernels back to back,
    since the host round-trip it replaces was a lossless f32 copy), and
    ``out[1]`` accumulates each sub-block's ``xsum`` — which equals the
    host combiner's ``(1 + valid) * x_avg`` weight-times-average — gated
    on ``valid > 0`` exactly like the host loop skips empty blocks.
    """
    row_grad = _row_grad_sq if loss == LOSS_SQUARED else _row_grad_log

    def kernel(
        x_ref, y_ref, m_ref, s_ref, z_ref, mu_ref, c_ref, gamma_ref, eta_ref, out_ref
    ):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            out_ref[...] = s_ref[...]

        X = x_ref[...]  # [B, d] — this grid step's sub-block
        y = y_ref[...]
        mask = m_ref[...]
        z = z_ref[...]
        mu = mu_ref[...]
        center = c_ref[...]
        gamma = gamma_ref[0]
        eta = eta_ref[0]
        x0 = out_ref[0, :]  # carried iterate (s_ref at step 0)

        if solver == "svrg":

            def body(r, carry):
                x, xsum, cnt = carry
                xi = X[r]
                yi = y[r]
                mi = mask[r]
                g = row_grad(xi, yi, x) - row_grad(xi, yi, z) + mu + gamma * (x - center)
                x_new = x - eta * g
                x = jnp.where(mi > 0, x_new, x)
                xsum = xsum + jnp.where(mi > 0, x, jnp.zeros_like(x))
                cnt = cnt + mi
                return (x, xsum, cnt)

            x, xsum, cnt = jax.lax.fori_loop(
                0, X.shape[0], body, (x0, x0, jnp.ones((), DTYPE))
            )
        else:  # saga
            n_valid = jnp.maximum(jnp.sum(mask), 1.0)
            alpha0 = _link_residual(loss, jnp.dot(X, z), y)

            def body(r, carry):
                x, gbar, alpha, xsum, cnt = carry
                xi = X[r]
                yi = y[r]
                mi = mask[r]
                s_new = _link_residual(loss, jnp.dot(xi, x), yi)
                diff = s_new - alpha[r]
                g = diff * xi + gbar + gamma * (x - center)
                x_new = x - eta * g
                x = jnp.where(mi > 0, x_new, x)
                gbar = jnp.where(mi > 0, gbar + (diff / n_valid) * xi, gbar)
                alpha = alpha.at[r].set(jnp.where(mi > 0, s_new, alpha[r]))
                xsum = xsum + jnp.where(mi > 0, x, jnp.zeros_like(x))
                cnt = cnt + mi
                return (x, gbar, alpha, xsum, cnt)

            x, _gbar, _alpha, xsum, cnt = jax.lax.fori_loop(
                0, X.shape[0], body, (x0, mu, alpha0, x0, jnp.ones((), DTYPE))
            )

        valid = cnt - 1.0
        out_ref[0, :] = x
        out_ref[1, :] += jnp.where(valid > 0, xsum, jnp.zeros_like(xsum))

    return kernel


def vr_chain(solver: str, loss: str, k: int, X, y, mask, S, z, mu, center, gamma, eta):
    """Chained K-block VR sweep over the state ``S = [x; avg_accum]``.

    One dispatch advances the iterate through K stacked blocks and folds
    each block's weighted average contribution into ``S[1]``; the host
    divides by the (pack-time-known) total weight via ``vr_avg`` at sweep
    end.  ``solver`` is ``svrg`` or ``saga`` (same duality as the
    per-block kernels).
    """
    if solver not in ("svrg", "saga"):
        raise ValueError(f"unknown VR solver {solver}")
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    rows, d = X.shape
    b = _check_width(rows, k)
    return pl.pallas_call(
        _make_vr_chain_kernel(solver, loss),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((STATE_ROWS, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((STATE_ROWS, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((STATE_ROWS, d), DTYPE),
        interpret=True,
    )(X, y, mask, S, z, mu, center, gamma, eta)


def _vscale_kernel(x_ref, s_ref, out_ref):
    out_ref[...] = s_ref[0] * x_ref[...]


def vec_scale(x, s):
    """``s * x`` with a shape-(1,) scalar operand."""
    (d,) = x.shape
    return pl.pallas_call(
        _vscale_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(x, s)


def _vaxpby_kernel(u_ref, v_ref, a_ref, b_ref, out_ref):
    out_ref[...] = a_ref[0] * u_ref[...] + b_ref[0] * v_ref[...]


def vec_axpby(u, v, a, b):
    """``a*u + b*v`` with shape-(1,) scalar operands."""
    (d,) = u.shape
    return pl.pallas_call(
        _vaxpby_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(u, v, a, b)


def _vdot_kernel(u_ref, v_ref, out_ref):
    out_ref[...] = jnp.sum(u_ref[...] * v_ref[...], keepdims=True)


def vec_dot(u, v):
    """``<u, v>`` as a shape-(1,) array — the CG loop's O(1) downlink."""
    return pl.pallas_call(
        _vdot_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), DTYPE),
        interpret=True,
    )(u, v)


def _vravg_kernel(s_ref, invw_ref, out_ref):
    invw = invw_ref[0]
    # invw == 0 encodes "no valid rows swept": fall back to the carried
    # iterate, mirroring the host combiner's empty-sweep fallback.
    out_ref[...] = jnp.where(invw > 0, invw * s_ref[1, :], s_ref[0, :])


def vr_avg(S, invw):
    """Sweep average ``S[1] / total_weight`` (``invw = 1/total_weight``).

    ``invw == 0`` returns ``S[0]`` (the unchanged iterate) — the host
    passes 0 when every swept block was empty, matching the legacy
    per-block combiner's fallback.
    """
    _, d = S.shape
    return pl.pallas_call(
        _vravg_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(S, invw)


def _vrreset_kernel(s_ref, out_ref):
    out_ref[0, :] = s_ref[0, :]
    out_ref[1, :] = jnp.zeros_like(s_ref[1, :])


def vr_reset(S):
    """New-sweep state: keep the carried iterate, zero the accumulator."""
    rows, d = S.shape
    return pl.pallas_call(
        _vrreset_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, d), DTYPE),
        interpret=True,
    )(S)


def _make_reduce_kernel(m: int):
    """Weighted mean over m machine vectors, f64 in host order.

    Mirrors the rust host collective operation-for-operation: an f64
    accumulator starting at zero, machine-order multiply-adds, an f64
    weight total, one reciprocal, one f64 multiply, one f32 downcast.
    Because every step is the same IEEE-754 operation on the same values,
    the result is bit-identical to ``Network::all_reduce_weighted`` — the
    property the device-collective parity test pins down.
    """

    def kernel(*refs):
        v_refs = refs[:m]
        w_ref = refs[m]
        out_ref = refs[m + 1]
        w = w_ref[...].astype(jnp.float64)
        acc = jnp.zeros_like(v_refs[0][...], dtype=jnp.float64)
        wtot = jnp.zeros((), jnp.float64)
        for i in range(m):
            acc = acc + w[i] * v_refs[i][...].astype(jnp.float64)
            wtot = wtot + w[i]
        inv = jnp.where(wtot > 0, 1.0 / wtot, jnp.zeros((), jnp.float64))
        out_ref[...] = (acc * inv).astype(DTYPE)

    return kernel


def reduce_weighted(m: int, vs, w):
    """Cross-machine weighted mean of ``m`` device vectors.

    ``vs`` is a sequence of m ``[d]`` vectors, ``w`` an ``[m]`` weight
    vector (weights must be f32-exact — counts are).  The f64 interior
    requires x64 to be active *around the whole trace*: callers wrap the
    call (or its ``jax.jit(...).lower``) in ``with enable_x64():`` — a
    mid-trace toggle would leave the outer trace's dtypes inconsistent.
    ``aot.py`` does this per-artifact (``ArtifactSpec.x64``) so every
    other kernel's lowering stays byte-identical to the x32 default.
    """
    if len(vs) != m:
        raise ValueError(f"expected {m} machine vectors, got {len(vs)}")
    if m < 2:
        raise ValueError(f"cross-machine reduce needs m >= 2, got {m}")
    (d,) = vs[0].shape
    return pl.pallas_call(
        _make_reduce_kernel(m),
        out_shape=jax.ShapeDtypeStruct((d,), DTYPE),
        interpret=True,
    )(*vs, w)
