"""Pure-jnp correctness oracles for every L1 kernel.

These are the ground truth the pytest/hypothesis suite compares the Pallas
kernels against, and the reference the rust integration tests re-derive
numerically.  Deliberately written in the most direct vectorized style —
no fusion tricks, no masking shortcuts beyond the spec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import LOSS_LOGISTIC, LOSS_SQUARED


def row_grad(loss: str, xi, yi, w):
    """Per-sample gradient of the instantaneous loss at w."""
    if loss == LOSS_SQUARED:
        return (jnp.dot(xi, w) - yi) * xi
    if loss == LOSS_LOGISTIC:
        t = -yi * jnp.dot(xi, w)
        return (-yi * jax.nn.sigmoid(t)) * xi
    raise ValueError(loss)


def row_loss(loss: str, xi, yi, w):
    if loss == LOSS_SQUARED:
        return 0.5 * (jnp.dot(xi, w) - yi) ** 2
    if loss == LOSS_LOGISTIC:
        return jnp.logaddexp(0.0, -yi * jnp.dot(xi, w))
    raise ValueError(loss)


def block_grad_ref(loss: str, X, y, mask, w):
    """Reference (grad_sum, loss_sum, count) over valid rows."""
    if loss == LOSS_SQUARED:
        r = (X @ w - y) * mask
        g = X.T @ r
        l = 0.5 * jnp.sum(mask * (X @ w - y) ** 2)
    elif loss == LOSS_LOGISTIC:
        t = -y * (X @ w)
        s = jax.nn.sigmoid(t)
        g = X.T @ (mask * (-y) * s)
        l = jnp.sum(mask * jnp.logaddexp(0.0, t))
    else:
        raise ValueError(loss)
    return g, jnp.reshape(l, (1,)), jnp.reshape(jnp.sum(mask), (1,))


def normal_matvec_ref(X, mask, v):
    """Reference X^T diag(mask) X v (sum form) + count."""
    u = (X @ v) * mask
    return X.T @ u, jnp.reshape(jnp.sum(mask), (1,))


def svrg_block_ref(loss: str, X, y, mask, x0, z, mu, wprev, gamma, eta):
    """Reference sequential SVRG sweep (plain python loop over rows).

    Semantics must match kernels/svrg.py exactly: padded rows are skipped,
    the running average includes x_0.
    """
    gamma = jnp.asarray(gamma).reshape(())
    eta = jnp.asarray(eta).reshape(())
    x = x0
    xsum = x0
    cnt = 1.0
    for r in range(X.shape[0]):
        if float(mask[r]) > 0:
            g = (
                row_grad(loss, X[r], y[r], x)
                - row_grad(loss, X[r], y[r], z)
                + mu
                + gamma * (x - wprev)
            )
            x = x - eta * g
            xsum = xsum + x
            cnt += 1.0
    return x, xsum / cnt


def link_residual_ref(loss: str, xi, yi, w):
    """Scalar GLM link residual: grad = s(w) * x."""
    z = jnp.dot(xi, w)
    if loss == LOSS_SQUARED:
        return z - yi
    return -yi * jax.nn.sigmoid(-yi * z)


def saga_block_ref(loss: str, X, y, mask, x0, z, mu, center, gamma, eta):
    """Reference sequential SAGA sweep (plain python loop over rows).

    Must mirror kernels/saga.py exactly: alpha initialized at the snapshot
    link residuals, gbar initialized at mu, per-row table updates, padded
    rows skipped, average includes x_0.
    """
    gamma = jnp.asarray(gamma).reshape(())
    eta = jnp.asarray(eta).reshape(())
    n_valid = max(float(jnp.sum(mask)), 1.0)
    alpha = [float(link_residual_ref(loss, X[r], y[r], z)) for r in range(X.shape[0])]
    x = x0
    gbar = mu
    xsum = x0
    cnt = 1.0
    for r in range(X.shape[0]):
        if float(mask[r]) > 0:
            s_new = link_residual_ref(loss, X[r], y[r], x)
            diff = s_new - alpha[r]
            g = diff * X[r] + gbar + gamma * (x - center)
            x = x - eta * g
            gbar = gbar + (diff / n_valid) * X[r]
            alpha[r] = float(s_new)
            xsum = xsum + x
            cnt += 1.0
    return x, xsum / cnt


def prox_objective_ref(loss: str, X, y, mask, w, wprev, gamma):
    """f_t(w) = (1/n_valid) sum_i l(w, xi_i) + gamma/2 ||w - wprev||^2."""
    _, lsum, cnt = block_grad_ref(loss, X, y, mask, w)
    n = jnp.maximum(cnt[0], 1.0)
    return lsum[0] / n + 0.5 * gamma * jnp.sum((w - wprev) ** 2)
