"""L1 Pallas kernel: sequential variance-reduced (SVRG) block pass.

This is step 2 of Algorithm 1 (MP-DSVRG) and the local prox-SVRG solve of
Algorithm 2 (MP-DANE): one machine sweeps a local batch *without
replacement*, applying per-sample variance-reduced updates for the proximal
objective

    f_t(w) = phi_I(w) + gamma/2 ||w - w_prev||^2 .

Per valid row xi (label yi), with snapshot ``z`` and full minibatch gradient
``mu = grad phi_I(z)``:

    g  = dl(x, xi) - dl(z, xi) + mu + gamma * (x - w_prev)
    x <- x - eta * g

The sweep has a true loop-carried dependence (each update feeds the next),
so — exactly like the paper runs it on a *single* machine per round — it is
a single-program kernel with a ``fori_loop`` over rows.  All operands stay
VMEM-resident; per-row work is two dot products and rank-1 AXPYs (VPU).

Following Algorithm 1 step 3, the running average includes the initial
iterate: ``x_avg = (1 / (1 + #valid)) * (x_0 + sum_r x_r)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, LOSS_LOGISTIC, LOSS_SQUARED


def _row_grad_sq(xi, yi, w):
    return (jnp.dot(xi, w) - yi) * xi


def _row_grad_log(xi, yi, w):
    t = -yi * jnp.dot(xi, w)
    return (-yi * jax.nn.sigmoid(t)) * xi


def _make_svrg_kernel(loss: str):
    row_grad = _row_grad_sq if loss == LOSS_SQUARED else _row_grad_log

    def kernel(
        x_ref, y_ref, m_ref, x0_ref, z_ref, mu_ref, wp_ref, gamma_ref, eta_ref,
        xout_ref, xavg_ref,
    ):
        X = x_ref[...]  # [B, d]
        y = y_ref[...]  # [B]
        mask = m_ref[...]  # [B]
        z = z_ref[...]  # snapshot iterate
        mu = mu_ref[...]  # full minibatch gradient at z
        wp = wp_ref[...]  # prox center w_{t-1}
        gamma = gamma_ref[0]
        eta = eta_ref[0]
        x0 = x0_ref[...]

        def body(r, carry):
            x, xsum, cnt = carry
            xi = X[r]
            yi = y[r]
            mi = mask[r]
            g = row_grad(xi, yi, x) - row_grad(xi, yi, z) + mu + gamma * (x - wp)
            x_new = x - eta * g
            # Padded rows are a strict no-op: neither update nor average.
            x = jnp.where(mi > 0, x_new, x)
            xsum = xsum + jnp.where(mi > 0, x, jnp.zeros_like(x))
            cnt = cnt + mi
            return (x, xsum, cnt)

        # The average includes x_0 (Algorithm 1 sums r = 0 .. |B|).
        x, xsum, cnt = jax.lax.fori_loop(
            0, X.shape[0], body, (x0, x0, jnp.ones((), DTYPE))
        )
        xout_ref[...] = x
        xavg_ref[...] = xsum / cnt

    return kernel


def svrg_block(loss: str, X, y, mask, x0, z, mu, wprev, gamma, eta):
    """One without-replacement SVRG sweep over a block.

    ``gamma`` and ``eta`` are shape-(1,) f32 arrays (scalar operands).
    Returns ``(x_out[d], x_avg[d])``.
    """
    if loss not in (LOSS_SQUARED, LOSS_LOGISTIC):
        raise ValueError(f"unknown loss {loss}")
    b, d = X.shape
    return pl.pallas_call(
        _make_svrg_kernel(loss),
        out_shape=(
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((d,), DTYPE),
        ),
        interpret=True,
    )(X, y, mask, x0, z, mu, wprev, gamma, eta)
