"""AOT manifest round-trip tests (uses a tmp dir; does not touch artifacts/)."""

from __future__ import annotations

import json
import os

import pytest

from compile.aot import emit_all


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # a single small config keeps the test fast; the full set is covered by
    # `make artifacts` + the rust integration tests
    manifest = emit_all(str(out), block=8, dims=(2,))
    return str(out), manifest


def test_manifest_files_exist(emitted):
    out, manifest = emitted
    assert manifest["block"] == 8
    assert manifest["dims"] == [2]
    # tupled: (grad+svrg+saga) x2 losses + nm, plus (gradm x2 + nmm) x2 widths = 13
    # chained: 3 widths x (2 gacc + 2 svrgc + 2 sagac + nacc) + 5 vec + 3 redm = 29
    assert len(manifest["artifacts"]) == 42
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule")


def test_manifest_json_round_trip(emitted):
    out, manifest = emitted
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk == json.loads(json.dumps(manifest))


def test_manifest_hashes_match(emitted):
    import hashlib

    out, manifest = emitted
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]


CHAINED_KINDS = ("gacc", "nacc", "svrgc", "sagac",
                 "vscale", "vaxpby", "vdot", "vravg", "vrreset", "red")


def test_manifest_shapes_are_lists(emitted):
    _, manifest = emitted
    for a in manifest["artifacts"]:
        assert all(isinstance(s, list) for s in a["arg_shapes"])
        assert a["kind"] in ("grad", "svrg", "saga", "nm", "grad_multi", "nm_multi") + CHAINED_KINDS
        assert a["block"] == 8
        assert a["chained"] == (a["kind"] in CHAINED_KINDS)


def test_manifest_multi_widths(emitted):
    _, manifest = emitted
    multi = [a for a in manifest["artifacts"] if a["kind"] in ("grad_multi", "nm_multi")]
    assert {a["k"] for a in multi} == {4, 8}
    for a in multi:
        # stacked operands: first arg is [k*block, d]
        assert a["arg_shapes"][0][0] == a["k"] * a["block"]
        assert a["name"].startswith(("gradm", "nmm"))
    singles = [
        a
        for a in manifest["artifacts"]
        if a["kind"] in ("grad", "svrg", "saga", "nm")
    ]
    assert all(a["k"] == 1 for a in singles)


def test_manifest_chained_widths(emitted):
    _, manifest = emitted
    chained = [a for a in manifest["artifacts"] if a["chained"]]
    block_kinds = ("gacc", "nacc", "svrgc", "sagac")
    assert {a["k"] for a in chained if a["kind"] in block_kinds} == {1, 4, 8}
    for a in chained:
        if a["kind"] in block_kinds:
            assert a["arg_shapes"][0][0] == a["k"] * a["block"]
        elif a["kind"] == "red":
            # k records the machine count M: M vectors + one [M] weight arg
            assert len(a["arg_shapes"]) == a["k"] + 1
            assert a["arg_shapes"][-1] == [a["k"]]
