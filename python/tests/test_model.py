"""L2 registry + lowering tests: shapes, tuple structure, manifest fields."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import (
    BLOCK,
    CHAIN_KS,
    DIMS,
    MULTI_KS,
    RED_MS,
    STATE_ROWS,
    artifact_name,
    chain_artifact_name,
    multi_artifact_name,
    red_artifact_name,
    vec_artifact_name,
)
from compile.model import build_registry, lower_to_hlo_text

VEC_KINDS = ("vscale", "vaxpby", "vdot", "vravg", "vrreset")


@pytest.fixture(scope="module")
def registry():
    return build_registry()


def test_registry_is_complete(registry):
    # tupled: 2 losses x 2 dims x (grad + svrg + saga) + 2 nm
    #   + 2 widths x 2 dims x (2 gradm + nmm) = 26
    # chained: per dim, 3 widths x (2 gacc + 2 svrgc + 2 sagac + nacc)
    #   + 5 vec-plane + 3 redm = 29
    per_dim_chained = len(CHAIN_KS) * 7 + len(VEC_KINDS) + len(RED_MS)
    assert len(registry) == 14 + len(MULTI_KS) * len(DIMS) * 3 + len(DIMS) * per_dim_chained
    for d in DIMS:
        for loss in ("sq", "log"):
            assert artifact_name("grad", loss, d) in registry
            assert artifact_name("svrg", loss, d) in registry
            assert artifact_name("saga", loss, d) in registry
            for k in MULTI_KS:
                assert multi_artifact_name("grad", loss, d, k) in registry
            for k in CHAIN_KS:
                assert chain_artifact_name("gacc", loss, d, k) in registry
                assert chain_artifact_name("svrgc", loss, d, k) in registry
                assert chain_artifact_name("sagac", loss, d, k) in registry
        assert artifact_name("nm", "sq", d) in registry
        for k in MULTI_KS:
            assert multi_artifact_name("nm", "sq", d, k) in registry
        for k in CHAIN_KS:
            assert chain_artifact_name("nacc", "sq", d, k) in registry
        for kind in VEC_KINDS:
            assert vec_artifact_name(kind, d) in registry
        for m in RED_MS:
            assert red_artifact_name(m, d) in registry


def test_registry_shapes(registry):
    for spec in registry.values():
        assert spec.block == BLOCK
        if spec.kind == "grad":
            assert len(spec.arg_shapes) == 4
            assert spec.outputs == ("grad_sum", "loss_sum", "count")
        elif spec.kind in ("svrg", "saga"):
            assert len(spec.arg_shapes) == 9
            assert spec.arg_shapes[-1] == (1,)  # eta scalar operand
            assert spec.outputs == ("x_out", "x_avg")
        elif spec.kind == "nm":
            assert len(spec.arg_shapes) == 3
            assert spec.outputs == ("xtxv_sum", "count")
        elif spec.kind == "grad_multi":
            assert spec.k in MULTI_KS
            assert len(spec.arg_shapes) == 4
            assert spec.outputs == ("grad_sum", "loss_sum", "count")
        elif spec.kind == "nm_multi":
            assert spec.k in MULTI_KS
            assert len(spec.arg_shapes) == 3
            assert spec.outputs == ("xtxv_sum", "count")
        elif spec.kind == "gacc":
            assert spec.k in CHAIN_KS
            assert len(spec.arg_shapes) == 5
            assert spec.arg_shapes[-1] == (spec.d,)  # carried accumulator
        elif spec.kind == "nacc":
            assert spec.k in CHAIN_KS
            assert len(spec.arg_shapes) == 4
        elif spec.kind in ("svrgc", "sagac"):
            assert spec.k in CHAIN_KS
            assert len(spec.arg_shapes) == 9
            assert spec.arg_shapes[3] == (STATE_ROWS, spec.d)  # carried state
            assert spec.outputs == ("state",)
        elif spec.kind in VEC_KINDS:
            assert spec.k == 1
        elif spec.kind == "red":
            assert spec.k in RED_MS
            assert len(spec.arg_shapes) == spec.k + 1
            assert spec.arg_shapes[-1] == (spec.k,)  # machine weights
        else:
            raise AssertionError(f"unknown kind {spec.kind}")
        if spec.kind in ("grad", "svrg", "saga", "nm"):
            assert spec.k == 1
        # block operands only exist on the block-consuming kinds
        if spec.kind in ("grad", "svrg", "saga", "nm", "grad_multi", "nm_multi",
                         "gacc", "nacc", "svrgc", "sagac"):
            assert spec.arg_shapes[0] == (spec.k * BLOCK, spec.d)
        # single-output chained artifacts are flagged for the rust loader
        assert spec.chained == (
            spec.kind in ("gacc", "nacc", "svrgc", "sagac", "red") or spec.kind in VEC_KINDS
        )
        assert spec.x64 == (spec.kind == "red")


def test_grad_multi_lowering_contains_loop(registry):
    """The fused dispatch must lower its K-step grid to a rolled loop, not
    K unrolled block bodies."""
    spec = registry[multi_artifact_name("grad", "sq", 64, 8)]
    text = lower_to_hlo_text(spec)
    assert "while" in text, "expected the grid loop in the lowered multi kernel"
    assert len(text) < 100_000


def test_grad_artifact_fn_executes(registry):
    spec = registry[artifact_name("grad", "sq", 64)]
    rng = np.random.default_rng(1)
    args = [jnp.asarray(rng.normal(size=s).astype(np.float32)) for s in spec.arg_shapes]
    out = spec.fn(*args)
    assert isinstance(out, tuple) and len(out) == 3
    assert out[0].shape == (64,)
    assert out[1].shape == (1,)


def test_lowered_hlo_has_entry_tuple(registry):
    spec = registry[artifact_name("grad", "sq", 64)]
    text = lower_to_hlo_text(spec)
    assert "HloModule" in text
    # return_tuple=True: entry computation must return a tuple type
    head = text.splitlines()[0]
    assert "->(" in head.replace(" ", ""), head


def test_svrg_lowering_contains_loop(registry):
    """The sequential sweep must lower to an HLO while-loop (bounded by the
    block size), not an unrolled 256-body chain."""
    spec = registry[artifact_name("svrg", "sq", 64)]
    text = lower_to_hlo_text(spec)
    assert "while" in text, "expected a while loop in the lowered SVRG pass"
    # sanity: text is compact (unrolling would be >100KB)
    assert len(text) < 100_000


def test_chained_lowering_returns_bare_array(registry):
    """Chained artifacts must lower to a single non-tuple root so the rust
    engine can feed the output buffer straight into the next dispatch."""
    for name in ("gacc4_sq_d64", "svrgc8_log_d64", "vaxpby_d64", "redm4_d64"):
        head = lower_to_hlo_text(registry[name]).splitlines()[0].replace(" ", "")
        assert "->(" not in head, f"{name}: chained root must not be a tuple: {head}"
        assert "->f32[" in head, f"{name}: expected a bare f32 array root: {head}"


def test_reduce_lowering_is_f64_interior(registry):
    """The cross-machine reduce must carry f64 math (bitwise host parity)
    behind an all-f32 boundary, and x64 must not leak into other kernels."""
    text = lower_to_hlo_text(registry[red_artifact_name(4, 64)])
    assert "f64" in text, "reduce kernel lost its f64 interior"
    head = text.splitlines()[0].replace(" ", "")
    assert "f64" not in head, f"reduce boundary must stay f32: {head}"
    for other in ("grad_sq_d64", "svrgc4_sq_d64", "vdot_d64"):
        assert "f64" not in lower_to_hlo_text(registry[other]), f"x64 leaked into {other}"
