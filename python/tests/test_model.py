"""L2 registry + lowering tests: shapes, tuple structure, manifest fields."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import BLOCK, DIMS, MULTI_KS, artifact_name, multi_artifact_name
from compile.model import build_registry, lower_to_hlo_text


@pytest.fixture(scope="module")
def registry():
    return build_registry()


def test_registry_is_complete(registry):
    # 2 losses x 2 dims x (grad + svrg + saga) + 2 nm
    #   + 2 widths x 2 dims x (2 gradm + nmm) = 26
    assert len(registry) == 14 + len(MULTI_KS) * len(DIMS) * 3
    for d in DIMS:
        for loss in ("sq", "log"):
            assert artifact_name("grad", loss, d) in registry
            assert artifact_name("svrg", loss, d) in registry
            assert artifact_name("saga", loss, d) in registry
            for k in MULTI_KS:
                assert multi_artifact_name("grad", loss, d, k) in registry
        assert artifact_name("nm", "sq", d) in registry
        for k in MULTI_KS:
            assert multi_artifact_name("nm", "sq", d, k) in registry


def test_registry_shapes(registry):
    for spec in registry.values():
        assert spec.block == BLOCK
        assert spec.arg_shapes[0] == (spec.k * BLOCK, spec.d)
        if spec.kind == "grad":
            assert len(spec.arg_shapes) == 4
            assert spec.outputs == ("grad_sum", "loss_sum", "count")
        elif spec.kind in ("svrg", "saga"):
            assert len(spec.arg_shapes) == 9
            assert spec.arg_shapes[-1] == (1,)  # eta scalar operand
            assert spec.outputs == ("x_out", "x_avg")
        elif spec.kind == "nm":
            assert len(spec.arg_shapes) == 3
            assert spec.outputs == ("xtxv_sum", "count")
        elif spec.kind == "grad_multi":
            assert spec.k in MULTI_KS
            assert len(spec.arg_shapes) == 4
            assert spec.outputs == ("grad_sum", "loss_sum", "count")
        elif spec.kind == "nm_multi":
            assert spec.k in MULTI_KS
            assert len(spec.arg_shapes) == 3
            assert spec.outputs == ("xtxv_sum", "count")
        else:
            raise AssertionError(f"unknown kind {spec.kind}")
        if spec.kind in ("grad", "svrg", "saga", "nm"):
            assert spec.k == 1


def test_grad_multi_lowering_contains_loop(registry):
    """The fused dispatch must lower its K-step grid to a rolled loop, not
    K unrolled block bodies."""
    spec = registry[multi_artifact_name("grad", "sq", 64, 8)]
    text = lower_to_hlo_text(spec)
    assert "while" in text, "expected the grid loop in the lowered multi kernel"
    assert len(text) < 100_000


def test_grad_artifact_fn_executes(registry):
    spec = registry[artifact_name("grad", "sq", 64)]
    rng = np.random.default_rng(1)
    args = [jnp.asarray(rng.normal(size=s).astype(np.float32)) for s in spec.arg_shapes]
    out = spec.fn(*args)
    assert isinstance(out, tuple) and len(out) == 3
    assert out[0].shape == (64,)
    assert out[1].shape == (1,)


def test_lowered_hlo_has_entry_tuple(registry):
    spec = registry[artifact_name("grad", "sq", 64)]
    text = lower_to_hlo_text(spec)
    assert "HloModule" in text
    # return_tuple=True: entry computation must return a tuple type
    head = text.splitlines()[0]
    assert "->(" in head.replace(" ", ""), head


def test_svrg_lowering_contains_loop(registry):
    """The sequential sweep must lower to an HLO while-loop (bounded by the
    block size), not an unrolled 256-body chain."""
    spec = registry[artifact_name("svrg", "sq", 64)]
    text = lower_to_hlo_text(spec)
    assert "while" in text, "expected a while loop in the lowered SVRG pass"
    # sanity: text is compact (unrolling would be >100KB)
    assert len(text) < 100_000
