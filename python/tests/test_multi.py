"""Fused multi-block kernel parity — the L1 signal for the gradm/nmm path.

The multi-block kernels must equal both the pure-jnp oracle on the stacked
operands and the *sum of per-block dispatches* (the host-fallback path the
rust engine uses for ragged tails), across full, partial, interleaved-empty
and all-empty sub-block masks, on both losses. Deliberately hypothesis-free:
fixed seeds enumerate the structural cases.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from jax.experimental import enable_x64

from compile.kernels import (
    CHAIN_KS,
    LOSSES,
    MULTI_KS,
    RED_MS,
    block_grad,
    block_grad_multi,
    grad_acc,
    multi_artifact_name,
    nm_acc,
    normal_matvec,
    normal_matvec_multi,
    reduce_weighted,
    saga_block,
    svrg_block,
    vr_avg,
    vr_chain,
)
from compile.kernels import ref

B, D = 8, 4  # small sub-blocks keep interpret-mode pallas fast


def make_stack(k, valids, seed, labels="real"):
    """k stacked B-row blocks; ``valids[i]`` rows of block i are valid."""
    rng = np.random.default_rng(seed)
    rows = k * B
    X = rng.normal(size=(rows, D)).astype(np.float32)
    if labels == "sign":
        y = np.where(rng.normal(size=(rows,)) >= 0, 1.0, -1.0).astype(np.float32)
    else:
        y = rng.normal(size=(rows,)).astype(np.float32)
    mask = np.zeros((rows,), np.float32)
    for i, v in enumerate(valids):
        mask[i * B : i * B + min(v, B)] = 1.0
    w = rng.normal(size=(D,)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(w)


MASK_CASES = {
    "full": lambda k: [B] * k,
    "ragged_tail": lambda k: [B] * (k - 1) + [3],
    "interleaved_empty": lambda k: [(B if i % 2 == 0 else 0) for i in range(k)],
    "all_empty": lambda k: [0] * k,
}


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_grad_multi_matches_ref_and_per_block(loss, k, case):
    valids = MASK_CASES[case](k)
    X, y, mask, w = make_stack(k, valids, 7, "sign" if loss == "log" else "real")
    g, l, c = block_grad_multi(loss, k, X, y, mask, w)
    # oracle on the stacked operands
    gr, lr, cr = ref.block_grad_ref(loss, X, y, mask, w)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(l, lr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, cr)
    assert float(c[0]) == sum(valids)
    # per-block dispatch sum (the rust host-fallback path)
    gs, ls, cs = np.zeros(D, np.float64), 0.0, 0.0
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        gi, li, ci = block_grad(loss, X[sl], y[sl], mask[sl], w)
        gs += np.asarray(gi, np.float64)
        ls += float(li[0])
        cs += float(ci[0])
    np.testing.assert_allclose(g, gs, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(l[0]), ls, rtol=1e-4, atol=1e-5)
    assert float(c[0]) == cs


@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_nm_multi_matches_ref_and_per_block(k, case):
    valids = MASK_CASES[case](k)
    X, _, mask, v = make_stack(k, valids, 13)
    o, c = normal_matvec_multi(k, X, mask, v)
    orf, crf = ref.normal_matvec_ref(X, mask, v)
    np.testing.assert_allclose(o, orf, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, crf)
    os_, cs = np.zeros(D, np.float64), 0.0
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        oi, ci = normal_matvec(X[sl], mask[sl], v)
        os_ += np.asarray(oi, np.float64)
        cs += float(ci[0])
    np.testing.assert_allclose(o, os_, rtol=1e-4, atol=1e-5)
    assert float(c[0]) == cs


def test_multi_rejects_bad_widths():
    X, y, mask, w = make_stack(2, [B, B], 1)
    with pytest.raises(ValueError):
        block_grad_multi("sq", 3, X, y, mask, w)  # 16 rows not divisible by 3
    with pytest.raises(ValueError):
        normal_matvec_multi(0, X, mask, w)


def test_multi_artifact_names():
    assert multi_artifact_name("grad", "sq", 64, 4) == "gradm4_sq_d64"
    assert multi_artifact_name("nm", "sq", 128, 8) == "nmm8_sq_d128"
    with pytest.raises(ValueError):
        multi_artifact_name("svrg", "sq", 64, 4)  # tupled VR sweeps stay per-block
    with pytest.raises(ValueError):
        multi_artifact_name("grad", "sq", 64, 1)
    with pytest.raises(ValueError):
        multi_artifact_name("nm", "log", 64, 4)  # nm is squared-loss only


# ---------------------------------------------------------------------------
# chained (single-output) kernel parity — the device-resident vector plane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("k", CHAIN_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_grad_acc_chains_per_block_sums(loss, k, case):
    """gacc == carried accumulator + the per-block grad sums, in order."""
    valids = MASK_CASES[case](k)
    X, y, mask, w = make_stack(k, valids, 23, "sign" if loss == "log" else "real")
    rng = np.random.default_rng(29)
    acc0 = rng.normal(size=(D,)).astype(np.float32)
    got = grad_acc(loss, k, X, y, mask, w, jnp.asarray(acc0))
    expect = acc0.copy()
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        gi, _, _ = block_grad(loss, X[sl], y[sl], mask[sl], w)
        expect = (expect + np.asarray(gi)).astype(np.float32)
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("k", CHAIN_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_nm_acc_chains_per_block_sums(k, case):
    valids = MASK_CASES[case](k)
    X, _, mask, v = make_stack(k, valids, 31)
    rng = np.random.default_rng(37)
    acc0 = rng.normal(size=(D,)).astype(np.float32)
    got = nm_acc(k, X, mask, v, jnp.asarray(acc0))
    expect = acc0.copy()
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        oi, _ = normal_matvec(X[sl], mask[sl], v)
        expect = (expect + np.asarray(oi)).astype(np.float32)
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("solver", ["svrg", "saga"])
@pytest.mark.parametrize("k", CHAIN_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_vr_chain_matches_legacy_per_block_sweep(loss, solver, k, case):
    """The group-aligned sweep must reproduce the legacy path: per-block
    kernels chained through the host, empty blocks skipped, averages
    combined with (1 + valid) weights."""
    valids = MASK_CASES[case](k)
    X, y, mask, _ = make_stack(k, valids, 41, "sign" if loss == "log" else "real")
    rng = np.random.default_rng(43)
    x0 = rng.normal(size=(D,)).astype(np.float32) * 0.3
    z = rng.normal(size=(D,)).astype(np.float32) * 0.1
    mu = rng.normal(size=(D,)).astype(np.float32) * 0.1
    center = np.zeros(D, np.float32)
    gamma = jnp.asarray([0.5], jnp.float32)
    eta = jnp.asarray([0.03], jnp.float32)
    S0 = jnp.asarray(np.stack([x0, np.zeros(D, np.float32)]))
    S1 = np.asarray(
        vr_chain(solver, loss, k, X, y, mask, S0, z, mu, center, gamma, eta)
    )
    # legacy: per-block dispatch, host-carried iterate, weighted host avg
    kern = svrg_block if solver == "svrg" else saga_block
    x = x0.copy()
    acc = np.zeros(D, np.float64)
    wsum = 0.0
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        v = int(mask[sl].sum())
        if v == 0:
            continue  # legacy sweep skips empty blocks entirely
        xo, xa = kern(loss, X[sl], y[sl], mask[sl], x, z, mu, center, gamma, eta)
        acc += (1 + v) * np.asarray(xa, np.float64)
        wsum += 1 + v
        x = np.asarray(xo)
    # carried iterate: bitwise (the host round-trip it replaces was lossless)
    np.testing.assert_array_equal(S1[0], x)
    if wsum > 0:
        got_avg = np.asarray(
            vr_avg(jnp.asarray(S1), jnp.asarray([1.0 / wsum], jnp.float32))
        )
        np.testing.assert_allclose(got_avg, (acc / wsum).astype(np.float32),
                                   rtol=1e-4, atol=1e-5)
    else:
        # all-empty sweep: invw=0 falls back to the unchanged iterate
        got_avg = np.asarray(vr_avg(jnp.asarray(S1), jnp.asarray([0.0], jnp.float32)))
        np.testing.assert_array_equal(got_avg, x0)


@pytest.mark.parametrize("m", RED_MS)
@pytest.mark.parametrize("weights", ["unit", "counts"])
def test_reduce_weighted_bitwise_matches_host_collective(m, weights):
    """redm{M} must be BIT-identical to the rust host collective: an f64
    accumulator from zero, machine-order multiply-adds, one reciprocal."""
    rng = np.random.default_rng(47 + m)
    vs = [rng.normal(size=(D,)).astype(np.float32) for _ in range(m)]
    w = (
        np.ones(m, np.float32)
        if weights == "unit"
        else rng.integers(1, 1 << 20, m).astype(np.float32)
    )
    with enable_x64():
        got = np.asarray(reduce_weighted(m, [jnp.asarray(v) for v in vs], jnp.asarray(w)))
    s = np.zeros(D, np.float64)
    wtot = 0.0
    for wi, v in zip(w, vs):
        wtot += float(wi)
        s += float(wi) * v.astype(np.float64)
    expect = (s * (1.0 / wtot)).astype(np.float32)
    np.testing.assert_array_equal(got.view(np.uint32), expect.view(np.uint32))


def test_chained_width_validation():
    X, y, mask, w = make_stack(2, [B, B], 1)
    acc = jnp.zeros((D,), jnp.float32)
    with pytest.raises(ValueError):
        grad_acc("sq", 3, X, y, mask, w, acc)  # 16 rows not divisible by 3
    with pytest.raises(ValueError):
        nm_acc(0, X, mask, w, acc)
    with pytest.raises(ValueError):
        vr_chain("sgd", "sq", 2, X, y, mask, None, w, w, w, None, None)  # bad solver
    with pytest.raises(ValueError):
        reduce_weighted(1, [w], jnp.ones((1,), jnp.float32))  # m < 2
