"""Fused multi-block kernel parity — the L1 signal for the gradm/nmm path.

The multi-block kernels must equal both the pure-jnp oracle on the stacked
operands and the *sum of per-block dispatches* (the host-fallback path the
rust engine uses for ragged tails), across full, partial, interleaved-empty
and all-empty sub-block masks, on both losses. Deliberately hypothesis-free:
fixed seeds enumerate the structural cases.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import (
    LOSSES,
    MULTI_KS,
    block_grad,
    block_grad_multi,
    multi_artifact_name,
    normal_matvec,
    normal_matvec_multi,
)
from compile.kernels import ref

B, D = 8, 4  # small sub-blocks keep interpret-mode pallas fast


def make_stack(k, valids, seed, labels="real"):
    """k stacked B-row blocks; ``valids[i]`` rows of block i are valid."""
    rng = np.random.default_rng(seed)
    rows = k * B
    X = rng.normal(size=(rows, D)).astype(np.float32)
    if labels == "sign":
        y = np.where(rng.normal(size=(rows,)) >= 0, 1.0, -1.0).astype(np.float32)
    else:
        y = rng.normal(size=(rows,)).astype(np.float32)
    mask = np.zeros((rows,), np.float32)
    for i, v in enumerate(valids):
        mask[i * B : i * B + min(v, B)] = 1.0
    w = rng.normal(size=(D,)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(w)


MASK_CASES = {
    "full": lambda k: [B] * k,
    "ragged_tail": lambda k: [B] * (k - 1) + [3],
    "interleaved_empty": lambda k: [(B if i % 2 == 0 else 0) for i in range(k)],
    "all_empty": lambda k: [0] * k,
}


@pytest.mark.parametrize("loss", LOSSES)
@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_grad_multi_matches_ref_and_per_block(loss, k, case):
    valids = MASK_CASES[case](k)
    X, y, mask, w = make_stack(k, valids, 7, "sign" if loss == "log" else "real")
    g, l, c = block_grad_multi(loss, k, X, y, mask, w)
    # oracle on the stacked operands
    gr, lr, cr = ref.block_grad_ref(loss, X, y, mask, w)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(l, lr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, cr)
    assert float(c[0]) == sum(valids)
    # per-block dispatch sum (the rust host-fallback path)
    gs, ls, cs = np.zeros(D, np.float64), 0.0, 0.0
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        gi, li, ci = block_grad(loss, X[sl], y[sl], mask[sl], w)
        gs += np.asarray(gi, np.float64)
        ls += float(li[0])
        cs += float(ci[0])
    np.testing.assert_allclose(g, gs, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(l[0]), ls, rtol=1e-4, atol=1e-5)
    assert float(c[0]) == cs


@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("case", sorted(MASK_CASES))
def test_nm_multi_matches_ref_and_per_block(k, case):
    valids = MASK_CASES[case](k)
    X, _, mask, v = make_stack(k, valids, 13)
    o, c = normal_matvec_multi(k, X, mask, v)
    orf, crf = ref.normal_matvec_ref(X, mask, v)
    np.testing.assert_allclose(o, orf, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, crf)
    os_, cs = np.zeros(D, np.float64), 0.0
    for i in range(k):
        sl = slice(i * B, (i + 1) * B)
        oi, ci = normal_matvec(X[sl], mask[sl], v)
        os_ += np.asarray(oi, np.float64)
        cs += float(ci[0])
    np.testing.assert_allclose(o, os_, rtol=1e-4, atol=1e-5)
    assert float(c[0]) == cs


def test_multi_rejects_bad_widths():
    X, y, mask, w = make_stack(2, [B, B], 1)
    with pytest.raises(ValueError):
        block_grad_multi("sq", 3, X, y, mask, w)  # 16 rows not divisible by 3
    with pytest.raises(ValueError):
        normal_matvec_multi(0, X, mask, w)


def test_multi_artifact_names():
    assert multi_artifact_name("grad", "sq", 64, 4) == "gradm4_sq_d64"
    assert multi_artifact_name("nm", "sq", 128, 8) == "nmm8_sq_d128"
    with pytest.raises(ValueError):
        multi_artifact_name("svrg", "sq", 64, 4)  # VR sweeps stay per-block
    with pytest.raises(ValueError):
        multi_artifact_name("grad", "sq", 64, 1)
    with pytest.raises(ValueError):
        multi_artifact_name("nm", "log", 64, 4)  # nm is squared-loss only
