"""Kernel-vs-reference correctness — the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle in ``ref.py`` across
random shapes, masks (including all-padded blocks) and parameter ranges.
Hypothesis drives the shape/value sweep; fixed-seed tests pin exact
regression cases at the production block size.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings

from compile.kernels import (
    BLOCK,
    LOSSES,
    block_grad,
    normal_matvec,
    saga_block,
    svrg_block,
)
from compile.kernels import ref
from .conftest import block_shapes


def make_block(rows, dim, valid, seed, labels="real"):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(rows, dim)).astype(np.float32)
    if labels == "sign":
        y = np.where(rng.normal(size=(rows,)) >= 0, 1.0, -1.0).astype(np.float32)
    else:
        y = rng.normal(size=(rows,)).astype(np.float32)
    mask = (np.arange(rows) < min(valid, rows)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), rng


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=40, deadline=None)
@given(shape=block_shapes)
def test_block_grad_matches_ref(loss, shape):
    rows, dim, valid, seed = shape
    X, y, mask, rng = make_block(rows, dim, valid, seed, "sign" if loss == "log" else "real")
    w = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    g, l, c = block_grad(loss, X, y, mask, w)
    gr, lr, cr = ref.block_grad_ref(loss, X, y, mask, w)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(l, lr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, cr)


@settings(max_examples=40, deadline=None)
@given(shape=block_shapes)
def test_normal_matvec_matches_ref(shape):
    rows, dim, valid, seed = shape
    X, _, mask, rng = make_block(rows, dim, valid, seed)
    v = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    o, c = normal_matvec(X, mask, v)
    orf, crf = ref.normal_matvec_ref(X, mask, v)
    np.testing.assert_allclose(o, orf, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, crf)


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=15, deadline=None)
@given(shape=block_shapes)
def test_svrg_block_matches_ref(loss, shape):
    rows, dim, valid, seed = shape
    X, y, mask, rng = make_block(rows, dim, valid, seed, "sign" if loss == "log" else "real")
    vec = lambda: jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    x0, z, mu, wp = vec(), vec(), vec(), vec()
    gamma = jnp.asarray([abs(float(rng.normal())) + 0.1], jnp.float32)
    eta = jnp.asarray([0.01], jnp.float32)
    xo, xa = svrg_block(loss, X, y, mask, x0, z, mu, wp, gamma, eta)
    xor_, xar = ref.svrg_block_ref(loss, X, y, mask, x0, z, mu, wp, gamma, eta)
    np.testing.assert_allclose(xo, xor_, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(xa, xar, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("loss", LOSSES)
@settings(max_examples=15, deadline=None)
@given(shape=block_shapes)
def test_saga_block_matches_ref(loss, shape):
    rows, dim, valid, seed = shape
    X, y, mask, rng = make_block(rows, dim, valid, seed, "sign" if loss == "log" else "real")
    vec = lambda: jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    x0, z, mu, c = vec(), vec(), vec(), vec()
    gamma = jnp.asarray([abs(float(rng.normal())) + 0.1], jnp.float32)
    eta = jnp.asarray([0.01], jnp.float32)
    xo, xa = saga_block(loss, X, y, mask, x0, z, mu, c, gamma, eta)
    xor_, xar = ref.saga_block_ref(loss, X, y, mask, x0, z, mu, c, gamma, eta)
    np.testing.assert_allclose(xo, xor_, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(xa, xar, rtol=1e-3, atol=1e-4)


def test_saga_first_steps_match_svrg():
    """With alpha initialized at the snapshot, SAGA's *first* row update
    coincides with SVRG's (same control variate before any table update)."""
    rows, dim = 1, 5
    X, y, mask, rng = make_block(rows, dim, rows, 13)
    vec = lambda: jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    x0, z, mu, wp = vec(), vec(), vec(), vec()
    gamma = jnp.asarray([0.5], jnp.float32)
    eta = jnp.asarray([0.05], jnp.float32)
    xs, _ = svrg_block("sq", X, y, mask, x0, z, mu, wp, gamma, eta)
    xg, _ = saga_block("sq", X, y, mask, x0, z, mu, wp, gamma, eta)
    np.testing.assert_allclose(xs, xg, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("loss", LOSSES)
def test_padding_is_noop(loss):
    """Gradient of a padded block == gradient of the compact data."""
    rows, dim, valid = BLOCK, 64, 100
    X, y, mask, rng = make_block(rows, dim, valid, 7, "sign" if loss == "log" else "real")
    w = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    g_pad, l_pad, c_pad = block_grad(loss, X, y, mask, w)
    g_cut, l_cut, c_cut = ref.block_grad_ref(
        loss, X[:valid], y[:valid], jnp.ones((valid,), jnp.float32), w
    )
    np.testing.assert_allclose(g_pad, g_cut, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(l_pad, l_cut, rtol=1e-4, atol=1e-5)
    assert float(c_pad[0]) == valid


def test_all_masked_block_is_zero():
    X, y, mask, rng = make_block(8, 4, 0, 3)
    w = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    g, l, c = block_grad("sq", X, y, mask, w)
    assert float(c[0]) == 0.0
    np.testing.assert_allclose(g, np.zeros(4), atol=1e-7)
    np.testing.assert_allclose(l, [0.0], atol=1e-7)


def test_svrg_zero_eta_is_identity():
    X, y, mask, rng = make_block(12, 6, 12, 11)
    vec = lambda: jnp.asarray(rng.normal(size=(6,)).astype(np.float32))
    x0, z, mu, wp = vec(), vec(), vec(), vec()
    xo, xa = svrg_block(
        "sq", X, y, mask, x0, z, mu, wp,
        jnp.asarray([1.0], jnp.float32), jnp.asarray([0.0], jnp.float32),
    )
    np.testing.assert_allclose(xo, x0, atol=1e-7)
    np.testing.assert_allclose(xa, x0, atol=1e-6)


def test_svrg_decreases_prox_objective():
    """On a well-conditioned least-squares block, one VR sweep with a sane
    stepsize must reduce the prox objective (the property Algorithm 1
    relies on: one pass per batch decreases the objective)."""
    rows, dim = BLOCK, 64
    X, y, mask, rng = make_block(rows, dim, rows, 5)
    X = X / np.sqrt(dim)  # row norms ~1 => smoothness ~1
    wp = jnp.zeros((dim,), jnp.float32)
    x0 = jnp.zeros((dim,), jnp.float32)
    gamma = jnp.asarray([1.0], jnp.float32)
    # mu = full prox gradient at snapshot z=x0
    gsum, _, cnt = ref.block_grad_ref("sq", X, y, mask, x0)
    mu = gsum / cnt[0]
    before = ref.prox_objective_ref("sq", X, y, mask, x0, wp, 1.0)
    xo, xa = svrg_block(
        "sq", X, y, mask, x0, x0, mu, wp, gamma, jnp.asarray([0.1], jnp.float32)
    )
    after = ref.prox_objective_ref("sq", X, y, mask, xa, wp, 1.0)
    assert float(after) < float(before)
