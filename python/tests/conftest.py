"""Shared fixtures/strategies for the kernel test suite."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import strategies as st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20170707)


def finite_f32(lo=-3.0, hi=3.0):
    return st.floats(
        min_value=lo, max_value=hi, allow_nan=False, allow_infinity=False, width=32
    )


# Hypothesis strategy: (rows, dim, valid_rows, seed). Shapes stay small so
# interpret-mode pallas is fast, but sweep odd sizes, full/empty masks.
block_shapes = st.tuples(
    st.integers(min_value=1, max_value=24),  # rows
    st.sampled_from([1, 2, 3, 5, 8, 16]),  # dim
    st.integers(min_value=0, max_value=24),  # valid rows (clipped to rows)
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
)
