//! Figure 3 (Appendix E) — THE END-TO-END DRIVER.
//!
//! Reproduces the paper's experimental protocol on the four Table-3
//! datasets (synthetic equivalents, DESIGN.md §3):
//!
//!   1. generate the dataset, *write it to a real libsvm file*, re-parse
//!      it through the libsvm reader (exercising the genuine data path);
//!   2. half for training (sharded across m machines), half held out for
//!      estimating the population objective;
//!   3. MP-DANE (R=1, kappa=0, one local SVRG pass per DANE round, K DANE
//!      rounds) vs distributed minibatch SGD, sweeping minibatch size b;
//!   4. report estimated population objective vs b — the paper's panels.
//!
//!     cargo run --release --example figure3_convergence [-- --full]
//!                        [--scale S] [--m M] [--dataset NAME]
//!
//! Default: reduced grid (m=8, K in {1,4,16}, 4 b values, all datasets,
//! ~8k training samples per dataset). --full: m in {4,8,16}, K in
//! {1,2,4,8,16} as in the paper.

use anyhow::Result;
use mbprox::algos::mbprox::MinibatchProx;
use mbprox::algos::minibatch_sgd::MinibatchSgd;
use mbprox::algos::solvers::dane::DaneSolver;
use mbprox::algos::{Method, RunContext};
use mbprox::coordinator::Runner;
use mbprox::data::sampler::{shard_ranges, VecStream};
use mbprox::data::table3::{DatasetSpec, ALL};
use mbprox::data::{libsvm, Loss, Sample, SampleStream};
use mbprox::theory::{self, ProblemConsts};
use mbprox::util::prng::Prng;

struct Args {
    full: bool,
    scale: f64,
    m_only: Option<usize>,
    dataset: Option<String>,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut a = Args { full: false, scale: 0.0, m_only: None, dataset: None };
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--full" => a.full = true,
            "--scale" => {
                i += 1;
                a.scale = argv[i].parse().unwrap();
            }
            "--m" => {
                i += 1;
                a.m_only = Some(argv[i].parse().unwrap());
            }
            "--dataset" => {
                i += 1;
                a.dataset = Some(argv[i].clone());
            }
            other => eprintln!("# ignoring arg {other}"),
        }
        i += 1;
    }
    a
}

/// Generate the dataset, round-trip it through a libsvm file, and split
/// train/eval halves.
fn load_dataset(spec: &DatasetSpec, scale: f64, seed: u64) -> Result<(Vec<Sample>, Vec<Sample>)> {
    let n_train = spec.n_train(scale);
    let n_eval = spec.n_eval(scale).min(4096);
    let mut stream = spec.stream(seed);
    let all = stream.draw_many(n_train + n_eval);

    let dir = std::env::temp_dir().join("mbprox_figure3");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{}.libsvm", spec.name));
    libsvm::write_samples(&path, &all)?;
    let parsed = libsvm::read_samples(&path, spec.dim)?;
    anyhow::ensure!(parsed.len() == all.len(), "libsvm round trip lost samples");

    let (train, eval) = parsed.split_at(n_train);
    Ok((train.to_vec(), eval.to_vec()))
}

/// Build a RunContext over a fixed training set sharded across m machines.
fn context_from_shards<'e>(
    runner: &'e mut Runner,
    train: &[Sample],
    eval: &[Sample],
    loss: Loss,
    m: usize,
    seed: u64,
) -> Result<RunContext<'e>> {
    let native_dim = train[0].x.len();
    let d = runner.engine.manifest().padded_dim(native_dim)?;
    let ranges = shard_ranges(train.len(), m);
    let root = Prng::seed_from_u64(seed);
    let streams: Vec<Box<dyn SampleStream>> = (0..m)
        .map(|i| {
            let shard: Vec<Sample> = train[ranges[i].clone()].to_vec();
            Box::new(VecStream::new(shard, loss, root.split(i as u64))) as Box<dyn SampleStream>
        })
        .collect();
    runner.context_over(loss, d, streams, eval, 0)
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    runner: &mut Runner,
    train: &[Sample],
    eval: &[Sample],
    spec: &DatasetSpec,
    m: usize,
    b: usize,
    k_dane: Option<usize>, // None = minibatch SGD
    seed: u64,
) -> Result<(f64, u64, u64)> {
    let n = train.len() as f64;
    let consts = ProblemConsts {
        l_lipschitz: 1.0,
        b_norm: match spec.loss {
            Loss::Squared => (spec.dim as f64).sqrt(),
            Loss::Logistic => 2.0 * (spec.dim as f64).sqrt(),
        },
        beta_smooth: match spec.loss {
            Loss::Squared => 1.0,
            Loss::Logistic => 0.25,
        },
        m,
    };
    let plan = theory::mbprox_plan(&consts, n, b);
    let mut ctx = context_from_shards(runner, train, eval, spec.loss, m, seed)?;
    let result = match k_dane {
        Some(k) => {
            let eta = 0.1 / (consts.beta_smooth + plan.gamma);
            let mut method = MinibatchProx::new(
                "mp-dane",
                b,
                plan.t_outer,
                plan.gamma,
                DaneSolver::plain(k, eta),
            );
            method.run(&mut ctx)?
        }
        None => {
            let gamma = theory::minibatch_sgd_gamma(&consts, plan.t_outer, plan.bm);
            let mut method = MinibatchSgd { b_local: b, t_outer: plan.t_outer, gamma };
            method.run(&mut ctx)?
        }
    };
    Ok((
        result.final_objective.unwrap_or(f64::NAN),
        result.report.comm_rounds,
        result.report.vec_ops,
    ))
}

fn main() -> Result<()> {
    let args = parse_args();
    let mut runner = Runner::from_env()?;

    let ms: Vec<usize> = match args.m_only {
        Some(m) => vec![m],
        None if args.full => vec![4, 8, 16],
        None => vec![8],
    };
    let ks: Vec<usize> = if args.full { vec![1, 2, 4, 8, 16] } else { vec![1, 4, 16] };
    let bs: Vec<usize> = if args.full {
        vec![32, 64, 128, 256, 512, 1024]
    } else {
        vec![32, 128, 512, 1024]
    };

    println!("# Figure 3 — estimated population objective vs minibatch size b");
    println!("dataset,m,method,K,b,objective,comm_rounds,vec_ops");
    for spec in ALL {
        if let Some(only) = &args.dataset {
            if only != spec.name {
                continue;
            }
        }
        // default scale: ~8k training samples per dataset
        let scale = if args.scale > 0.0 {
            args.scale
        } else {
            (8192.0 / (spec.n_total as f64 / 2.0)).min(1.0)
        };
        let (train, eval) = load_dataset(spec, scale, 20170707)?;
        eprintln!(
            "# {}: {} train / {} eval samples (dim {}, {:?}, scale {:.4})",
            spec.name,
            train.len(),
            eval.len(),
            spec.dim,
            spec.loss,
            scale
        );
        for &m in &ms {
            for &b in &bs {
                if b * m > train.len() {
                    continue;
                }
                for &k in &ks {
                    let (obj, rounds, ops) =
                        run_one(&mut runner, &train, &eval, spec, m, b, Some(k), 1)?;
                    println!("{},{m},mp-dane,{k},{b},{obj:.6},{rounds},{ops}", spec.name);
                }
                let (obj, rounds, ops) =
                    run_one(&mut runner, &train, &eval, spec, m, b, None, 1)?;
                println!("{},{m},minibatch-sgd,0,{b},{obj:.6},{rounds},{ops}", spec.name);
            }
        }
    }
    Ok(())
}
