//! Quickstart: run MP-DSVRG on a planted least-squares problem and watch
//! the population objective fall to the noise floor.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack: synthetic per-machine streams -> block
//! packing -> AOT Pallas/JAX artifacts on the PJRT runtime -> the
//! minibatch-prox outer loop with the distributed-SVRG inner solver ->
//! resource accounting in the paper's units.

use anyhow::Result;
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::metrics;

fn main() -> Result<()> {
    let mut runner = Runner::from_env()?;
    println!(
        "engine: platform={} artifacts={} block={}",
        runner.engine.platform(),
        runner.engine.manifest().artifacts.len(),
        runner.engine.block_rows()
    );

    let cfg = ExperimentConfig {
        m: 4,
        b_local: 512,
        n_budget: 65_536,
        loss: Loss::Squared,
        dim: 64,
        seed: 7,
        eval_samples: 4096,
        eval_every: 4,
        method: "mp-dsvrg".into(),
        ..ExperimentConfig::default()
    };
    println!(
        "\nrunning {} on planted least squares (m={}, b={}, n={})",
        cfg.method, cfg.m, cfg.b_local, cfg.n_budget
    );
    println!("noise floor (Bayes objective) = 0.005\n");

    let result = runner.run(&cfg)?;
    println!("{}", metrics::curve_csv(&result));
    println!("{}", metrics::resource_table(&[&result]));

    let obj = result.final_objective.unwrap_or(f64::NAN);
    println!(
        "final population objective {:.5} (excess over floor {:.5})",
        obj,
        obj - 0.005
    );
    Ok(())
}
