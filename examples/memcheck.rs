//! Memory-regression probe for the engine hot path.
//!
//! The xla crate's literal-input `execute` leaks its internal
//! literal->buffer conversions (~70 KB/call measured); the engine
//! therefore runs everything through `execute_b` with caller-managed
//! device buffers. This probe fails loudly if per-call RSS growth
//! reappears. Run: `cargo run --release --example _leak_probe`

use mbprox::data::blocks::pack_block;
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::runtime::exec::BlockLits;
use mbprox::runtime::Engine;

fn rss_kb() -> u64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    s.lines()
        .find(|l| l.starts_with("VmRSS"))
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap()
}

fn main() {
    let mut e = Engine::new(std::path::Path::new("artifacts")).unwrap();
    let mut stream = SynthStream::new(SynthSpec::least_squares(64), 1);
    let samples = stream.draw_many(256);
    let block = pack_block(&samples, 64);
    let lits = BlockLits::from_block(&mut e, &block).unwrap();
    let w = vec![0.01f32; 64];
    let z = vec![0.0f32; 64];

    // warmup: compile + first dispatches
    for _ in 0..100 {
        e.grad_block(Loss::Squared, &lits, &w).unwrap();
        e.svrg_block(Loss::Squared, &lits, &w, &z, &z, &z, 0.5, 0.01).unwrap();
    }
    let baseline = rss_kb();
    println!("baseline after warmup: {baseline} kB");
    for round in 0..3 {
        for _ in 0..5000 {
            e.grad_block(Loss::Squared, &lits, &w).unwrap();
        }
        for _ in 0..1000 {
            e.svrg_block(Loss::Squared, &lits, &w, &z, &z, &z, 0.5, 0.01).unwrap();
        }
        println!("after round {}: {} kB", round + 1, rss_kb());
    }
    let growth = rss_kb().saturating_sub(baseline);
    println!("total growth over 18k calls: {growth} kB");
    assert!(growth < 60_000, "engine hot path leaks: {growth} kB over 18k calls");
    println!("LEAK CHECK OK");
}
