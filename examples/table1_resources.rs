//! Table 1 — resources required by each method on the distributed
//! stochastic least-squares problem, measured per machine in vectors.
//!
//!     cargo run --release --example table1_resources [n_budget] [m]
//!
//! Prints the measured counters next to the paper's asymptotic predictions
//! (theory::predict_*). Absolute constants differ (ours include the log
//! factors the paper suppresses); the *orderings and scalings* are the
//! claims under test — see EXPERIMENTS.md §Table 1.

use anyhow::Result;
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::{problem_consts, Runner};
use mbprox::data::Loss;
use mbprox::metrics;
use mbprox::theory;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_budget: usize = args.first().map(|s| s.parse().unwrap()).unwrap_or(65_536);
    let m: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(4);

    let mut runner = Runner::from_env()?;
    let base = ExperimentConfig {
        m,
        n_budget,
        loss: Loss::Squared,
        dim: 64,
        seed: 99,
        eval_samples: 4096,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    let c = problem_consts(&base);
    let n = n_budget as f64;
    let b_max = n_budget / m;

    // (method, b_local) rows mirroring Table 1 top-to-bottom
    let rows: Vec<(&str, &str, usize)> = vec![
        ("Ideal (local SGD, 1 machine)", "local-sgd", 256),
        ("Acc. minibatch SGD", "acc-minibatch-sgd", 64),
        ("Minibatch SGD", "minibatch-sgd", 64),
        ("DANE (ERM)", "dane-erm", 0),
        ("DiSCO (ERM)", "disco-erm", 0),
        ("AGD (ERM)", "agd-erm", 0),
        ("DSVRG (ERM)", "dsvrg-erm", 0),
        ("MP-DSVRG (b = 256)", "mp-dsvrg", 256),
        ("MP-DSVRG (b = 1024)", "mp-dsvrg", 1024),
        ("MP-DSVRG (b = b_max)", "mp-dsvrg", b_max),
        ("MP-DANE  (b = 256)", "mp-dane", 256),
        ("MP-oneshot/EMSO (b = 256)", "mp-oneshot", 256),
    ];

    println!("Table 1 — measured resources (n = {n_budget}, m = {m}, squared loss)\n");
    let mut results = Vec::new();
    for (label, method, b) in &rows {
        let cfg = ExperimentConfig {
            method: method.to_string(),
            b_local: if *b == 0 { 256 } else { *b },
            m: if *method == "local-sgd" { 1 } else { m },
            ..base.clone()
        };
        match runner.run(&cfg) {
            Ok(mut r) => {
                r.name = label.to_string();
                results.push(r);
            }
            Err(e) => eprintln!("{label}: {e}"),
        }
    }
    let refs: Vec<&_> = results.iter().collect();
    print!("{}", metrics::resource_table(&refs));

    println!("\npaper predictions (per machine, ignoring constants/logs):");
    println!(
        "{:<28} {:>14} {:>14} {:>10}",
        "method", "communication", "computation", "memory"
    );
    let pred = [
        ("Acc. minibatch SGD", theory::predict_acc_minibatch_sgd(&c, n)),
        ("DSVRG (ERM)", theory::predict_dsvrg_erm(&c, n)),
        ("MP-DSVRG (b = 256)", theory::predict_mp_dsvrg(&c, n, 256)),
        ("MP-DSVRG (b = 1024)", theory::predict_mp_dsvrg(&c, n, 1024)),
        ("MP-DSVRG (b = b_max)", theory::predict_mp_dsvrg(&c, n, b_max)),
        ("MP-DANE  (b = 256)", theory::predict_mp_dane(&c, n, 256, 64)),
    ];
    for (name, p) in pred {
        println!(
            "{:<28} {:>14.1} {:>14.1} {:>10.1}",
            name, p.communication, p.computation, p.memory
        );
    }
    Ok(())
}
