//! Figures 1 & 2 — the communication/memory/computation tradeoff of
//! MP-DSVRG (and friends) as the minibatch size b sweeps from small to
//! b_max = n/m.
//!
//!     cargo run --release --example tradeoff_sweep [--figure2] [n] [m]
//!
//! Figure 1 (default): MP-DSVRG only — communication falls ~1/b while
//! memory rises ~b, computation flat (the paper's headline tradeoff).
//! Figure 2 (--figure2): overlays acc-minibatch-SGD, MP-DANE, DSVRG-ERM
//! so the crossovers of the schematic are measurable.

use anyhow::Result;
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let figure2 = args.iter().any(|a| a == "--figure2");
    let nums: Vec<usize> =
        args.iter().filter(|a| !a.starts_with("--")).map(|s| s.parse().unwrap()).collect();
    let n_budget = nums.first().copied().unwrap_or(65_536);
    let m = nums.get(1).copied().unwrap_or(4);

    let mut runner = Runner::from_env()?;
    let base = ExperimentConfig {
        m,
        n_budget,
        loss: Loss::Squared,
        dim: 64,
        seed: 31,
        eval_samples: 2048,
        eval_every: 0,
        ..ExperimentConfig::default()
    };

    let methods: Vec<&str> = if figure2 {
        vec!["mp-dsvrg", "mp-dane", "acc-minibatch-sgd", "minibatch-sgd"]
    } else {
        vec!["mp-dsvrg"]
    };

    println!(
        "# {} — n={n_budget}, m={m}, squared loss",
        if figure2 { "Figure 2" } else { "Figure 1" }
    );
    println!("method,b_local,comm_rounds,vec_ops,peak_memory,sim_time_s,objective");
    for method in methods {
        let mut b = 64usize;
        let b_max = n_budget / m;
        while b <= b_max {
            let cfg = ExperimentConfig {
                method: method.to_string(),
                b_local: b,
                ..base.clone()
            };
            match runner.run(&cfg) {
                Ok(r) => {
                    println!(
                        "{method},{b},{},{},{},{:.5},{}",
                        r.report.comm_rounds,
                        r.report.vec_ops,
                        r.report.peak_vectors,
                        r.sim_time_s,
                        r.final_objective.map(|o| format!("{o:.6}")).unwrap_or_default()
                    );
                }
                Err(e) => eprintln!("# {method} b={b}: {e}"),
            }
            b *= 4;
        }
    }
    // reference points for Figure 2's right edge: the ERM batch methods
    if figure2 {
        for method in ["dsvrg-erm", "dane-erm", "disco-erm"] {
            let cfg = ExperimentConfig { method: method.to_string(), ..base.clone() };
            match runner.run(&cfg) {
                Ok(r) => println!(
                    "{method},{},{},{},{},{:.5},{}",
                    n_budget / m,
                    r.report.comm_rounds,
                    r.report.vec_ops,
                    r.report.peak_vectors,
                    r.sim_time_s,
                    r.final_objective.map(|o| format!("{o:.6}")).unwrap_or_default()
                ),
                Err(e) => eprintln!("# {method}: {e}"),
            }
        }
    }
    Ok(())
}
