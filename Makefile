# Build artifacts, run the tier-1 gate, and the benches.
#
# `artifacts` lowers every registry kernel to HLO text + manifest.json into
# rust/artifacts/ (the path the rust tests and benches resolve via
# CARGO_MANIFEST_DIR). Python only runs here — never on the request path.

ARTIFACTS := rust/artifacts

.PHONY: artifacts pytest test bench bench-gate fmt lint doc clean

artifacts:
	cd python && python -m compile.aot --out-dir ../$(ARTIFACTS)

pytest:
	cd python && python -m pytest tests -q

test: artifacts
	cd rust && cargo test -q

bench: artifacts
	cd rust && cargo bench

# diff the fresh BENCH_runtime.json against the committed baseline bounds
# (run `make bench` first; CI runs this after its bench leg)
bench-gate:
	cd rust && cargo run --release --bin bench_gate -- BENCH_baseline.json BENCH_runtime.json

fmt:
	cd rust && cargo fmt --check

lint:
	cd rust && cargo clippy --all-targets -- -D warnings

doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

clean:
	rm -rf $(ARTIFACTS) rust/target
